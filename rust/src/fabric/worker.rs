//! The fabric worker: a stateless engine pool that pulls jobs from a remote
//! coordinator over `DPTNET01` frames.
//!
//! A worker process owns engines and nothing else — no store, no journal,
//! no scheduler state. It connects, proves it is the same build looking at
//! the same artifacts + corpus (the Hello handshake), announces one slot
//! per engine thread, and then executes whatever [`WorkItem`]s arrive,
//! reporting each `JobOutput` back as a `Done` frame. The engine threads
//! are byte-for-byte the in-process pool's [`worker_loop`] — the transport
//! cannot change what a job computes, which is the whole determinism story.
//!
//! Liveness: the worker heartbeats every ~2s (also while its engines are
//! busy — the routing thread never blocks on a job), so a coordinator can
//! tell a long job from a dead process. If the coordinator vanishes
//! mid-sweep the worker errors out; after a clean `Shutdown` frame it
//! exits 0.
//!
//! `max_jobs` is a failure-injection drill, not a production knob: after
//! executing its quota the worker *defects* — drops the connection on the
//! next assignment without executing it, exactly like a crashed machine —
//! so reassignment is testable deterministically (see the CI distributed
//! smoke and `tests/integration.rs`).

use std::io::BufReader;
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::ProgressSink;
use crate::data::Corpus;
use crate::exec::pool::{worker_loop, WorkerMsg};
use crate::exec::sched::WorkItem;
use crate::runtime::Manifest;
use crate::store::{RunStore, STORE_VERSION};

use super::wire::{self, Msg};

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Engine threads (slots) this process contributes.
    pub workers: usize,
    /// Shared whole-line progress sink for the engine threads' drivers.
    pub progress: Option<ProgressSink>,
    /// Failure-injection: execute at most this many jobs, then drop the
    /// connection on the next assignment without executing it.
    pub max_jobs: Option<usize>,
}

impl Default for WorkerOptions {
    fn default() -> WorkerOptions {
        WorkerOptions { workers: 1, progress: None, max_jobs: None }
    }
}

/// How a worker session ended (both are process-exit-0 outcomes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerReport {
    /// Jobs fully executed and reported.
    pub jobs_executed: usize,
    /// Ended by `max_jobs` defection rather than a coordinator `Shutdown`.
    pub defected: bool,
}

/// Internal event stream: engine-pool replies and decoded frames merge
/// into one queue so the routing loop has a single blocking point.
enum WEvent {
    Pool(WorkerMsg),
    Net(Msg),
    NetGone(String),
}

/// Connect to a coordinator and serve jobs until it says `Shutdown` (or
/// `max_jobs` defection). The manifest + corpus must describe the same
/// world as the coordinator's — the handshake refuses anything else.
pub fn run_worker(
    addr: &str,
    manifest: &Manifest,
    corpus: &Corpus,
    opts: &WorkerOptions,
) -> Result<WorkerReport> {
    if opts.workers == 0 {
        bail!("a fabric worker needs at least one engine thread (got --workers 0)");
    }
    let stream = TcpStream::connect(addr).with_context(|| {
        format!(
            "connecting to fabric coordinator at '{addr}' \
             (malformed address, or no `repro serve` listening there?)"
        )
    })?;
    stream.set_nodelay(true).ok();
    let mut write = stream.try_clone().context("cloning fabric socket")?;
    let mut read = BufReader::new(stream);

    // Handshake, synchronously: preamble both ways, Hello out,
    // Welcome/Reject back.
    wire::write_magic(&mut write)?;
    wire::expect_magic(&mut read)?;
    wire::send_msg(
        &mut write,
        &Msg::Hello {
            proto: wire::PROTOCOL_VERSION,
            store_version: STORE_VERSION as u64,
            salt: RunStore::context_salt(manifest, corpus),
            probe: wire::codec_probe()?,
        },
        manifest,
    )?;
    match wire::recv_msg(&mut read, manifest).context("waiting for the coordinator's welcome")? {
        Msg::Welcome => {}
        Msg::Reject { reason } => bail!("coordinator rejected this worker: {reason}"),
        _ => bail!("coordinator answered the handshake with an unexpected frame"),
    }

    thread::scope(|scope| -> Result<WorkerReport> {
        let (event_tx, event_rx) = channel::<WEvent>();

        // Engine pool: identical threads to the in-process pool.
        let (pool_tx, pool_rx) = channel::<WorkerMsg>();
        let mut to_engine: Vec<Sender<WorkItem>> = Vec::with_capacity(opts.workers);
        for w in 0..opts.workers {
            let (tx, rx) = channel::<WorkItem>();
            to_engine.push(tx);
            let replies = pool_tx.clone();
            let progress = opts.progress.clone();
            scope.spawn(move || worker_loop(w, manifest, corpus, rx, replies, progress));
        }
        drop(pool_tx);
        {
            let tx = event_tx.clone();
            scope.spawn(move || {
                for msg in pool_rx {
                    if tx.send(WEvent::Pool(msg)).is_err() {
                        return;
                    }
                }
            });
        }
        // Frame reader: decoded coordinator frames into the same queue.
        {
            let tx = event_tx.clone();
            scope.spawn(move || {
                loop {
                    match wire::recv_msg(&mut read, manifest) {
                        Ok(msg) => {
                            let stop = matches!(msg, Msg::Shutdown);
                            if tx.send(WEvent::Net(msg)).is_err() || stop {
                                return;
                            }
                        }
                        Err(e) => {
                            let _ = tx.send(WEvent::NetGone(format!("{e:#}")));
                            return;
                        }
                    }
                }
            });
        }
        drop(event_tx);

        let mut assigned = 0usize;
        let mut executed = 0usize;
        let mut alive = opts.workers;
        let mut last_beat = Instant::now();
        let finish = |write: &TcpStream, executed: usize, defected: bool| {
            let _ = write.shutdown(Shutdown::Both);
            Ok(WorkerReport { jobs_executed: executed, defected })
        };
        loop {
            match event_rx.recv_timeout(Duration::from_millis(500)) {
                Ok(WEvent::Pool(WorkerMsg::Ready { worker })) => {
                    wire::send_msg(&mut write, &Msg::Ready { slot: worker as u64 }, manifest)
                        .context("announcing an engine slot")?;
                }
                Ok(WEvent::Pool(WorkerMsg::Done { worker, job, output })) => {
                    executed += 1;
                    let output = output.map_err(|e| format!("{e:#}"));
                    let msg = Msg::Done { slot: worker as u64, job, output };
                    wire::send_msg(&mut write, &msg, manifest)
                        .context("reporting a finished job")?;
                }
                Ok(WEvent::Pool(WorkerMsg::Dead { error })) => {
                    alive -= 1;
                    if alive == 0 {
                        let _ = write.shutdown(Shutdown::Both);
                        return Err(error.context("every engine thread failed to start"));
                    }
                    // Slots that never announced Ready are simply never
                    // assigned; the remaining engines keep serving.
                }
                Ok(WEvent::Net(Msg::Assign { slot, item })) => {
                    assigned += 1;
                    if opts.max_jobs.is_some_and(|max| assigned > max) {
                        // Defect: vanish exactly like a crashed machine —
                        // the assignment is neither executed nor answered.
                        return finish(&write, executed, true);
                    }
                    let idx = slot as usize;
                    if idx >= to_engine.len() {
                        let _ = write.shutdown(Shutdown::Both);
                        return Err(anyhow!("coordinator assigned to unknown slot {slot}"));
                    }
                    if to_engine[idx].send(item).is_err() {
                        let _ = write.shutdown(Shutdown::Both);
                        return Err(anyhow!("engine thread {idx} exited unexpectedly"));
                    }
                }
                Ok(WEvent::Net(Msg::Heartbeat)) => {}
                Ok(WEvent::Net(Msg::Shutdown)) => return finish(&write, executed, false),
                Ok(WEvent::Net(_)) => {
                    let _ = write.shutdown(Shutdown::Both);
                    return Err(anyhow!("unexpected fabric frame from the coordinator"));
                }
                Ok(WEvent::NetGone(e)) => {
                    return Err(anyhow!("lost connection to the fabric coordinator: {e}"));
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("worker internals disconnected unexpectedly"));
                }
            }
            // Liveness, even mid-job: this loop never blocks on an engine.
            if last_beat.elapsed() >= Duration::from_secs(2) {
                // A send failure here means the socket died; the reader
                // thread will surface it as NetGone with the real error.
                let _ = wire::send_msg(&mut write, &Msg::Heartbeat, manifest);
                last_beat = Instant::now();
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusConfig;

    fn tiny_world() -> (Manifest, Corpus) {
        let manifest = Manifest::parse(r#"{"configs":{}}"#, std::path::PathBuf::from("/tmp"))
            .expect("empty manifest parses");
        let cfg = CorpusConfig { vocab: 8, train_tokens: 64, val_tokens: 16, ..Default::default() };
        (manifest, Corpus::generate(cfg))
    }

    #[test]
    fn zero_engine_threads_is_a_friendly_error() {
        // No connection is attempted: the flag error must come first.
        let (manifest, corpus) = tiny_world();
        let opts = WorkerOptions { workers: 0, ..WorkerOptions::default() };
        let err = run_worker("127.0.0.1:1", &manifest, &corpus, &opts).unwrap_err();
        assert!(format!("{err:#}").contains("at least one engine thread"), "{err:#}");
    }

    #[test]
    fn connecting_nowhere_is_a_contextual_error() {
        let (manifest, corpus) = tiny_world();
        let opts = WorkerOptions::default();
        // A port nothing listens on: the error must say where and hint at
        // `repro serve`, not surface a bare io::Error.
        let err = run_worker("127.0.0.1:9", &manifest, &corpus, &opts).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fabric coordinator at '127.0.0.1:9'"), "{msg}");
        assert!(msg.contains("repro serve"), "{msg}");
    }
}
