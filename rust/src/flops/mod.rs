//! FLOP ledger: the paper's 6·B·T·N accounting (Eq. 1.1).
//!
//! Progressive training's headline claim is a *compute* ratio
//! (≈80% savings / ≈5× speedup at equal loss), which is hardware-independent
//! under the 6N-per-token convention the paper itself uses. MoE configs
//! count **active** parameters (router selects top_k of n_experts), matching
//! how the paper reports DeepSeekV3/Mixtral compute.

use crate::runtime::ConfigEntry;

/// FLOPs consumed by one train step of a config (fwd+bwd ≈ 6·N per token).
pub fn flops_per_step(entry: &ConfigEntry) -> f64 {
    6.0 * entry.active_param_count as f64 * entry.tokens_per_step() as f64
}

/// FLOPs for an eval step (forward only ≈ 2·N per token).
pub fn flops_per_eval(entry: &ConfigEntry) -> f64 {
    2.0 * entry.active_param_count as f64 * entry.tokens_per_step() as f64
}

/// Paper Eq. 1.1: progressive = 6B(τ·N_small + (T−τ)·N_large).
pub fn progressive_flops(small: &ConfigEntry, large: &ConfigEntry, tau: usize, total: usize) -> f64 {
    flops_per_step(small) * tau as f64 + flops_per_step(large) * (total - tau) as f64
}

/// Cumulative-FLOP ledger a run appends to as it steps through (possibly
/// several) model stages.
#[derive(Debug, Clone, Default)]
pub struct FlopLedger {
    pub total: f64,
    pub tokens: u64,
    /// (cfg_id, steps, flops) per stage, in order.
    pub stages: Vec<(String, usize, f64)>,
}

impl FlopLedger {
    pub fn record(&mut self, entry: &ConfigEntry, steps: usize) {
        let f = flops_per_step(entry) * steps as f64;
        self.total += f;
        self.tokens += (entry.tokens_per_step() * steps) as u64;
        match self.stages.last_mut() {
            Some((id, s, fl)) if *id == entry.cfg_id => {
                *s += steps;
                *fl += f;
            }
            _ => self.stages.push((entry.cfg_id.clone(), steps, f)),
        }
    }

    /// Savings vs a fixed-size run of `entry` for the same step count.
    pub fn savings_vs_fixed(&self, entry: &ConfigEntry) -> f64 {
        let steps: usize = self.stages.iter().map(|(_, s, _)| *s).sum();
        let fixed = flops_per_step(entry) * steps as f64;
        1.0 - self.total / fixed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Manifest, ConfigEntry};
    use std::path::PathBuf;

    fn fake(cfg_id: &str, params: usize, batch: usize, seq: usize) -> ConfigEntry {
        let text = format!(
            r#"{{"configs":{{"{cfg_id}":{{
            "model":{{"family":"gpt2","n_layer":1,"batch":{batch},"seq_len":{seq},"moe":null}},
            "opt":{{"kind":"muon_nsgd"}},"params":[],"opt_state":[],
            "param_count":{params},"active_param_count":{params},
            "chunk":8,"artifacts":{{}}}}}}}}"#
        );
        Manifest::parse(&text, PathBuf::from("/tmp")).unwrap().get(cfg_id).unwrap().clone()
    }

    #[test]
    fn eq_1_1_accounting() {
        let small = fake("s", 1_000, 8, 64);
        let large = fake("l", 10_000, 8, 64);
        let tau = 800;
        let total = 1000;
        let prog = progressive_flops(&small, &large, tau, total);
        let fixed = flops_per_step(&large) * total as f64;
        // N_small = N_large/10, τ = 0.8T: prog/fixed = 0.8*0.1 + 0.2 = 0.28.
        assert!((prog / fixed - 0.28).abs() < 1e-12);
    }

    #[test]
    fn ledger_matches_closed_form() {
        let small = fake("s", 1_000, 8, 64);
        let large = fake("l", 10_000, 8, 64);
        let mut led = FlopLedger::default();
        led.record(&small, 800);
        led.record(&large, 200);
        assert_eq!(led.stages.len(), 2);
        let expect = progressive_flops(&small, &large, 800, 1000);
        assert!((led.total - expect).abs() < 1.0);
        assert_eq!(led.tokens, 512 * 1000);
        assert!((led.savings_vs_fixed(&large) - 0.72).abs() < 1e-9);
    }

    #[test]
    fn ledger_merges_contiguous_stages() {
        let small = fake("s", 1_000, 8, 64);
        let mut led = FlopLedger::default();
        led.record(&small, 10);
        led.record(&small, 10);
        assert_eq!(led.stages.len(), 1);
        assert_eq!(led.stages[0].1, 20);
    }
}
