//! Deep Progressive Training: zero/one-layer depth expansion for efficient
//! pre-training — a rust + JAX + Pallas reproduction (AOT via PJRT).
//!
//! Layering (see DESIGN.md):
//! - [`runtime`]: loads AOT'd HLO-text artifacts and executes them (PJRT CPU).
//! - [`coordinator`]: the paper's contribution — progressive-training
//!   orchestration: expansion timing, mixing detection, multi-stage
//!   schedules, and probe-driven multi-round depth ladders
//!   (`RunBuilder::ladder` + `recipe::LadderController`). The v2 API is
//!   `RunBuilder` (validated plans) → `RunDriver` (resumable state machine)
//!   + `Observer` hooks + `Sweep` (work-sharing multi-run executor).
//! - [`exec`]: parallel execution — job-graph lowering of sweeps (nested
//!   multi-round trunk sharing) plus an engine-per-worker pool with a
//!   deterministic scheduler (bit-identical to serial execution for any
//!   worker count).
//! - [`store`]: durable sweep store — content-addressed run/trunk cache +
//!   crash-safe job journal; interrupted sweeps resume, warm reruns
//!   execute nothing.
//! - [`fabric`]: distributed sweep fabric — the same scheduler stretched
//!   over TCP: `repro serve` coordinator + `repro worker` fleets sharing
//!   one artifact repository, bit-identical to serial execution.
//! - [`expansion`]: depth-expansion engine (random/copying/zero/... of §3).
//! - [`schedule`]: WSD / cosine learning-rate schedules (§4's key lever).
//! - [`data`]: synthetic Markov-Zipf corpus with a known entropy floor.
//! - [`flops`]: 6·B·T·N compute ledger (paper Eq. 1.1 accounting).
//! - [`convex`]: §4 convergence-theory simulator.
//! - [`scaling`]: power-law fits for the Fig-2 scaling laws.
//! - [`metrics`]: loss curves, the §5 mixing detector, table/CSV writers.
//! - [`diag`]: depth-diagnostics observability — per-layer probe stats,
//!   the JSONL trace sink, and the `repro diagnose` verdict math (§11).
pub mod util;
pub mod runtime;
pub mod schedule;
pub mod data;
pub mod flops;
pub mod expansion;
pub mod metrics;
pub mod coordinator;
pub mod diag;
pub mod exec;
pub mod store;
pub mod fabric;
pub mod convex;
pub mod scaling;
pub mod checkpoint;
pub mod bench;
pub mod cli;
pub mod audit;
