//! `repro` — launcher for the Deep Progressive Training reproduction.
//!
//! Commands:
//!   train <cfg_id> [--steps N] [--sched wsd|cosine|constant] [--lr F]
//!         [--seed N] [--save-every N --ckpt-dir D] [--resume SNAP]
//!                                                   fixed-size training
//!   progressive <small> <large> [--tau N|--tau-frac F] [--steps N] ...
//!         [--strategy random|copying|zero|zero_n|zero_l] [--insertion top|bottom]
//!         [--spike-sigma S [--spike-window W]]         adaptive spike detector
//!   diagnose <small> <large> [--tau N|--tau-frac F] [--workers N] [--trace P]
//!         per-layer depth diagnostics: grown ladder vs FLOP-matched
//!         from-scratch baseline, depth profiles + curse-of-depth verdict
//!   sweep <small> <large> [--taus F,F,..] [--strategies a,b,..]
//!         [--workers N] [--progress] [--store-dir D]
//!         expansion-variant sweep sharing source-model training, executed
//!         over N engine-owning pool workers (bit-identical to serial);
//!         --store-dir makes it durable (crash-safe resume + warm reruns)
//!   ladder <cfg0> <cfg1> [<cfg2> ...] [--taus F,F,..|--probe] [--rewarm N]
//!         multi-round depth-ladder growth; --probe places each boundary
//!         from a per-round mixing probe (recipe::LadderController)
//!   probe-mixing <small> <large> [--probe-steps N] [--steps N] [--workers N]
//!         the paper's §7 recipe step 4: derive τ from two early-stopped runs
//!   convex [--dim N] [--tau-frac F]                 §4 theory simulator
//!   bench-<target>  (fig1..fig22, table1, table2, theory, perf, parallel, ladder, all)
//!   list / list-benches / inspect <cfg_id>
//!
//! Flags accept `--name value` and `--name=value`; unknown flags are
//! rejected per command. Python never runs here: artifacts are AOT'd once
//! by `make artifacts`.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Duration;

use anyhow::Result;
use deep_progressive::bench::{run_target, Ctx, ALL_TARGETS};
use deep_progressive::checkpoint;
use deep_progressive::cli::{Args, CommandSpec};
use deep_progressive::convex::{simulate, ConvexProblem, Teleport};
use deep_progressive::coordinator::{
    recipe, LossSpikeDetector, PeriodicCheckpointer, ProgressPrinter, ProgressSink, RunBuilder,
    RunDriver, RunPlan, Sweep, Trainer, TransferRule,
};
use deep_progressive::data::{Corpus, CorpusConfig};
use deep_progressive::diag;
use deep_progressive::exec::{default_workers, JobGraph};
use deep_progressive::expansion::{strategy_from_name, ExpandSpec, Insertion, OsPolicy};
use deep_progressive::fabric::{
    run_chaos, run_worker, FabricOptions, FabricServer, FaultSpec, WorkerOptions,
};
use deep_progressive::runtime::{Engine, Manifest};
use deep_progressive::schedule::Schedule;
use deep_progressive::store::RunStore;
use deep_progressive::util::json::Json;

fn spec_for(cmd: &str) -> Option<CommandSpec> {
    // Static per-command vocabularies so typos fail loudly instead of
    // silently parsing as switches (see cli.rs).
    const TRAIN: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "out", "steps", "seed", "lr", "sched", "decay-frac", "eval-every",
            "save-every", "ckpt-dir", "resume",
        ],
        switches: &["progress"],
    };
    const PROGRESSIVE: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "out", "steps", "seed", "lr", "sched", "decay-frac", "eval-every", "tau",
            "tau-frac", "strategy", "insertion", "os", "expand-seed", "spike-sigma",
            "spike-window",
        ],
        switches: &["progress"],
    };
    const DIAGNOSE: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "out", "steps", "seed", "lr", "sched", "decay-frac", "eval-every", "tau",
            "tau-frac", "strategy", "insertion", "os", "expand-seed", "workers", "store-dir",
            "trace",
        ],
        switches: &["progress"],
    };
    const SWEEP: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "out", "steps", "seed", "lr", "sched", "decay-frac", "eval-every", "taus",
            "strategies", "insertion", "os", "expand-seed", "workers", "store-dir", "transfer",
        ],
        switches: &["progress"],
    };
    const PROBE: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "out", "steps", "seed", "lr", "sched", "decay-frac", "probe-steps",
            "production-steps", "tol", "strategy", "insertion", "os", "expand-seed", "workers",
        ],
        switches: &[],
    };
    const LADDER: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "out", "steps", "seed", "lr", "sched", "decay-frac", "eval-every",
            "taus", "rewarm", "strategy", "strategies", "insertion", "os", "expand-seed",
            "workers", "store-dir", "probe-steps", "tol", "transfer",
        ],
        switches: &["progress", "probe"],
    };
    const SERVE: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "out", "steps", "seed", "lr", "sched", "decay-frac", "eval-every",
            "taus", "rewarm", "strategy", "strategies", "insertion", "os", "expand-seed",
            "workers", "store-dir", "listen", "heartbeat-timeout", "stats-json", "transfer",
        ],
        switches: &["progress", "resume"],
    };
    const WORKER: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "connect", "workers", "max-jobs", "retry-max", "retry-base", "fault",
        ],
        switches: &["progress"],
    };
    const CHAOS: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "steps", "seed", "lr", "sched", "decay-frac", "eval-every", "taus",
            "rewarm", "strategy", "strategies", "insertion", "os", "expand-seed", "timeout",
            "transfer",
        ],
        switches: &[],
    };
    const STORE: CommandSpec = CommandSpec {
        flags: &["store-dir", "keep"],
        switches: &["dry-run"],
    };
    const CONVEX: CommandSpec = CommandSpec {
        flags: &["steps", "seed", "lr", "sched", "decay-frac", "dim", "tau-frac"],
        switches: &[],
    };
    const EXPAND_CKPT: CommandSpec = CommandSpec {
        flags: &["artifacts", "in", "out-ckpt", "strategy", "insertion", "os", "expand-seed"],
        switches: &[],
    };
    const BENCH: CommandSpec = CommandSpec {
        flags: &["artifacts", "out", "steps", "seed", "workers", "store-dir"],
        switches: &[],
    };
    const LISTING: CommandSpec = CommandSpec { flags: &["artifacts"], switches: &[] };
    const AUDIT: CommandSpec = CommandSpec {
        flags: &["src-dir", "golden", "report", "budget", "sample", "seed"],
        switches: &["lints", "codecs", "model-check", "fix-allows", "bless"],
    };
    const VET: CommandSpec = CommandSpec {
        flags: &[
            "artifacts", "out", "steps", "seed", "lr", "sched", "decay-frac", "eval-every",
            "taus", "rewarm", "strategy", "strategies", "insertion", "os", "expand-seed",
            "transfer", "report", "waive",
        ],
        switches: &["fixtures"],
    };
    match cmd {
        "train" => Some(TRAIN),
        "progressive" => Some(PROGRESSIVE),
        "diagnose" => Some(DIAGNOSE),
        "sweep" => Some(SWEEP),
        "ladder" => Some(LADDER),
        "serve" => Some(SERVE),
        "worker" => Some(WORKER),
        "chaos" => Some(CHAOS),
        "store" => Some(STORE),
        "probe-mixing" => Some(PROBE),
        "convex" => Some(CONVEX),
        "expand-ckpt" => Some(EXPAND_CKPT),
        "audit" => Some(AUDIT),
        "vet" => Some(VET),
        "list" | "list-benches" | "inspect" => Some(LISTING),
        c if c.starts_with("bench-") => Some(BENCH),
        _ => None,
    }
}

fn schedule_from(args: &Args) -> Schedule {
    let lr = args.get_f32("lr", 0.01);
    match args.get_str("sched", "wsd") {
        "cosine" => Schedule::cosine(lr),
        "constant" => Schedule::Constant { peak: lr, warmup_frac: 0.02 },
        "linear" => Schedule::Linear { peak: lr, warmup_frac: 0.02 },
        _ => Schedule::Wsd { peak: lr, warmup_frac: 0.02, decay_frac: args.get_f32("decay-frac", 0.2) },
    }
}

fn expand_from(args: &Args) -> Result<ExpandSpec> {
    Ok(ExpandSpec {
        strategy: strategy_from_name(args.get_str("strategy", "random"))?,
        insertion: if args.get_str("insertion", "bottom") == "top" { Insertion::Top } else { Insertion::Bottom },
        os_policy: match args.get_str("os", "inherit") {
            "copy" => OsPolicy::Copy,
            "reset" => OsPolicy::Reset,
            _ => OsPolicy::Inherit,
        },
        seed: args.get_u64("expand-seed", 7),
    })
}

/// `--transfer`: HP-transfer rule metadata stamped on every plan in a grid
/// (DESIGN.md §13; the vet's transfer-mix lint rejects grids mixing rules).
fn transfer_from(args: &Args) -> Result<TransferRule> {
    TransferRule::from_name(args.get_str("transfer", "fixed"))
}

fn apply_eval_every(mut b: RunBuilder, args: &Args) -> RunBuilder {
    if args.get("eval-every").is_some() {
        b = b.eval_every(args.get_usize("eval-every", 1));
    }
    b
}

/// Required positional argument, as a friendly error instead of a panic.
fn positional<'a>(args: &'a Args, i: usize, usage: &str) -> Result<&'a str> {
    args.positional
        .get(i)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing argument — usage: {usage}"))
}

/// τ from a fraction of the horizon. Both the fraction (parsed as f64 —
/// an f32-encoded "0.8" is already off by whole steps past ~2^24) and the
/// product stay in f64, so large horizons keep integer precision.
fn tau_from_frac(steps: usize, frac: f64) -> usize {
    (steps as f64 * frac) as usize
}

/// `--workers` with a friendly floor: zero engines can execute nothing, so
/// an explicit 0 (or garbage) is an error instead of silently meaning
/// "serial" — `repro serve --workers 0` is the one place 0 is meaningful
/// (remote-only coordinator) and does not go through here.
fn workers_from(args: &Args) -> Result<usize> {
    match args.get("workers") {
        None => Ok(default_workers()),
        Some(s) => match s.parse::<usize>() {
            Ok(0) => anyhow::bail!(
                "--workers must be at least 1 (got 0); omit the flag to use every core"
            ),
            Ok(n) => Ok(n),
            Err(_) => anyhow::bail!("--workers expects a positive number, got '{s}'"),
        },
    }
}

/// Spike-detector settings for `progressive`: report-only (threshold 0) by
/// default; `--spike-sigma S [--spike-window W]` switches to the rolling
/// mode, flagging post-expansion jumps above S × the sample stddev of the
/// last W cadence evals.
fn spike_detector_from(args: &Args) -> Result<LossSpikeDetector> {
    let sigma = match args.get("spike-sigma") {
        None => {
            if let Some(w) = args.get("spike-window") {
                anyhow::bail!(
                    "--spike-window {w} only makes sense with --spike-sigma (without a sigma                      the detector uses an absolute threshold and keeps no rolling window)"
                );
            }
            return Ok(LossSpikeDetector::new(0.0));
        }
        Some(text) => match text.parse::<f32>() {
            Ok(v) if v.is_finite() && v > 0.0 => v,
            _ => anyhow::bail!(
                "--spike-sigma expects a positive number of standard deviations, got '{text}'"
            ),
        },
    };
    let window = match args.get("spike-window") {
        None => 8,
        Some(text) => match text.parse::<usize>() {
            Ok(w) if w >= 2 => w,
            _ => anyhow::bail!(
                "--spike-window expects an integer >= 2 (a rolling stddev needs at least                  two samples), got '{text}'"
            ),
        },
    };
    Ok(LossSpikeDetector::with_sigma(sigma, window))
}

/// Build the (non-probe) ladder grid shared by `ladder`, `serve`, and
/// `chaos` from CLI args — a thin adapter over [`recipe::ladder_grid`],
/// which owns the construction rules, so a fabric run's CSVs can be diffed
/// byte-for-byte against the serial ladder's.
fn ladder_grid(
    args: &Args,
    rungs: &[&str],
    steps: usize,
    seed: u64,
    sched: Schedule,
    usage: &str,
) -> Result<Vec<RunPlan>> {
    let spec = recipe::LadderGridSpec {
        rungs,
        steps,
        seed,
        sched,
        base: expand_from(args)?,
        rewarm: args.get_usize("rewarm", 0),
        transfer: transfer_from(args)?,
        taus: args
            .get("taus")
            .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect()),
        strategies: args
            .get("strategies")
            .map(|l| l.split(',').map(|s| s.trim().to_string()).collect()),
        eval_every: args.get("eval-every").map(|_| args.get_usize("eval-every", 1)),
    };
    recipe::ladder_grid(&spec).map_err(|e| anyhow::anyhow!("{e:#} — usage: {usage}"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let command = argv.first().cloned().unwrap_or_default();
    let args = match spec_for(&command) {
        Some(spec) => match Args::parse_for(argv, &spec) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("error: {e} (command '{command}')\n{HELP}");
                std::process::exit(2);
            }
        },
        None => Args::parse(argv),
    };
    let artifacts = args.get_str("artifacts", "artifacts").to_string();
    let out = args.get_str("out", "results").to_string();
    let steps = args.get_usize("steps", 240);
    let seed = args.get_u64("seed", 17);

    match args.command.as_str() {
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        "list" => {
            let m = Manifest::load(&artifacts)?;
            for (id, c) in &m.configs {
                println!(
                    "{id:24} {} n_layer={:<3} params={:<9} active={:<9} artifacts={:?}",
                    c.model.family,
                    c.model.n_layer,
                    c.param_count,
                    c.active_param_count,
                    c.artifacts.keys().collect::<Vec<_>>()
                );
            }
            Ok(())
        }
        "list-benches" => {
            for t in ALL_TARGETS {
                println!("bench-{t}");
            }
            Ok(())
        }
        "inspect" => {
            let m = Manifest::load(&artifacts)?;
            let c = m.get(positional(&args, 0, "inspect <cfg_id>")?)?;
            println!("config {}: {} params, {} active", c.cfg_id, c.param_count, c.active_param_count);
            for p in &c.params {
                println!("  {:32} {:?} init={:?} muon={}", p.name, p.shape, p.init, p.muon);
            }
            Ok(())
        }
        "train" => {
            let engine = Engine::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let trainer = Trainer::new(&engine, &manifest, &corpus);
            let cfg_id = positional(&args, 0, "train <cfg_id>")?.to_string();
            let plan = apply_eval_every(
                RunBuilder::fixed(format!("train-{cfg_id}"), &cfg_id, steps, schedule_from(&args)).seed(seed),
                &args,
            )
            .build()?;
            let mut driver = match args.get("resume") {
                Some(p) => {
                    let path = std::path::Path::new(p);
                    let snap_cfg = checkpoint::snapshot_cfg_id(path)?;
                    let snap = checkpoint::load_snapshot(path, manifest.get(&snap_cfg)?)?;
                    println!("resuming '{}' from step {}", snap.run_name, snap.step);
                    RunDriver::resume(trainer, plan, snap)?
                }
                None => RunDriver::new(trainer, plan)?,
            };
            if args.has("progress") {
                driver.attach(Box::new(ProgressPrinter::default()));
            }
            let save_every = args.get_usize("save-every", 0);
            if save_every > 0 {
                driver.attach(Box::new(PeriodicCheckpointer::starting_at(
                    save_every,
                    args.get_str("ckpt-dir", "checkpoints"),
                    driver.step_index(),
                )));
            }
            driver.run_to_end()?;
            let res = driver.finish();
            res.curve.write_csv(std::path::Path::new(&out))?;
            println!(
                "final val loss {:.4} | {:.2e} FLOPs | {} tokens | entropy floor {:.3}",
                res.final_val_loss, res.ledger.total, res.ledger.tokens, corpus.entropy_floor
            );
            Ok(())
        }
        "progressive" => {
            let engine = Engine::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let trainer = Trainer::new(&engine, &manifest, &corpus);
            let small = positional(&args, 0, "progressive <small> <large>")?.to_string();
            let large = positional(&args, 1, "progressive <small> <large>")?.to_string();
            let tau = args
                .get("tau")
                .and_then(|s| s.parse().ok())
                .unwrap_or_else(|| tau_from_frac(steps, args.get_f64("tau-frac", 0.8)));
            let plan = apply_eval_every(
                RunBuilder::progressive(
                    format!("prog-{small}-{large}"),
                    &small,
                    &large,
                    tau,
                    steps,
                    schedule_from(&args),
                    expand_from(&args)?,
                )
                .seed(seed),
                &args,
            )
            .build()?;
            let mut driver = RunDriver::new(trainer, plan)?;
            if args.has("progress") {
                driver.attach(Box::new(ProgressPrinter::default()));
            }
            let spikes = Rc::new(RefCell::new(spike_detector_from(&args)?));
            driver.attach(Box::new(spikes.clone()));
            driver.run_to_end()?;
            let res = driver.finish();
            res.curve.write_csv(std::path::Path::new(&out))?;
            let fixed_flops = trainer.fixed_flops(&large, steps)?;
            println!(
                "final val loss {:.4} | {:.2e} FLOPs ({:.0}% saving vs fixed) | expansion at step {tau} (loss jump {:+.4})",
                res.final_val_loss,
                res.ledger.total,
                (1.0 - res.ledger.total / fixed_flops) * 100.0,
                spikes.borrow().max_jump().unwrap_or(f32::NAN),
            );
            Ok(())
        }
        "diagnose" => {
            // Depth diagnostics (DESIGN.md §11): one grown progressive run
            // and one FLOP-matched from-scratch baseline at the large depth,
            // both with per-layer probes on, compared layer by layer. Runs
            // through the sweep machinery, so --workers and --store-dir
            // behave exactly like sweep grids: any worker count (or a warm
            // store rerun, which executes nothing) emits byte-identical
            // diagnostics.
            const USAGE: &str = "diagnose <small> <large> [--tau N|--tau-frac F] [--steps N] \
                                 [--workers N] [--store-dir D] [--trace PATH]";
            let engine = Engine::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let trainer = Trainer::new(&engine, &manifest, &corpus);
            let small = positional(&args, 0, USAGE)?.to_string();
            let large = positional(&args, 1, USAGE)?.to_string();
            let tau = args
                .get("tau")
                .and_then(|s| s.parse().ok())
                .unwrap_or_else(|| tau_from_frac(steps, args.get_f64("tau-frac", 0.8)))
                .max(1);
            if tau >= steps {
                anyhow::bail!("--tau {tau} must be below --steps {steps} — usage: {USAGE}");
            }
            let sched = schedule_from(&args);
            let grown = apply_eval_every(
                RunBuilder::progressive(
                    format!("diag-grown-{small}-{large}"),
                    &small,
                    &large,
                    tau,
                    steps,
                    sched,
                    expand_from(&args)?,
                )
                .seed(seed)
                .diag(true),
                &args,
            )
            .build()?;
            // FLOP-match the baseline: a from-scratch run at the large depth
            // spending what the grown run spends, in large-config steps.
            let grown_flops =
                trainer.fixed_flops(&small, tau)? + trainer.fixed_flops(&large, steps - tau)?;
            let scratch_steps =
                ((grown_flops / trainer.fixed_flops(&large, 1)?).round() as usize).max(1);
            let scratch = apply_eval_every(
                RunBuilder::fixed(format!("diag-scratch-{large}"), &large, scratch_steps, sched)
                    .seed(seed)
                    .diag(true),
                &args,
            )
            .build()?;
            // Pre-flight vet before any store exists (DESIGN.md §13).
            deep_progressive::audit::vet::gate(
                &[grown.clone(), scratch.clone()],
                Some(&manifest),
                "diagnose",
            )?;
            let workers = workers_from(&args)?;
            let mut sweep = Sweep::new(trainer);
            if args.has("progress") {
                sweep.progress(ProgressSink::stderr());
            }
            if let Some(dir) = args.get("store-dir") {
                sweep.store(dir)?;
            }
            sweep.add(grown.clone());
            sweep.add(scratch.clone());
            let outcome = sweep.run_parallel(workers)?;
            let outdir = std::path::Path::new(&out);
            let trace = args
                .get("trace")
                .map(|p| diag::TraceSink::to_file(std::path::Path::new(p)))
                .transpose()?;
            for (plan, res) in [&grown, &scratch].iter().zip(&outcome.results) {
                res.curve.write_csv(outdir)?;
                diag::write_layer_stats_csv(outdir, plan.name(), &res.layer_stats)?;
                println!(
                    "\n{} (final val loss {:.4} | {:.2e} FLOPs):",
                    plan.name(),
                    res.final_val_loss,
                    res.ledger.total
                );
                print!("{}", diag::depth_profile(&res.layer_stats).render());
                if let Some(t) = &trace {
                    // Replay the persisted record as span events — identical
                    // output whether the runs executed now or came from a
                    // warm store.
                    let rows = &res.layer_stats;
                    let mut i = 0;
                    while i < rows.len() {
                        let mut j = i;
                        while j < rows.len()
                            && rows[j].step == rows[i].step
                            && rows[j].rung == rows[i].rung
                        {
                            j += 1;
                        }
                        t.emit(
                            "layer_stats",
                            &[
                                ("run", Json::Str(plan.name().to_string())),
                                ("cfg", Json::Str(rows[i].rung.clone())),
                                ("step", Json::Num(rows[i].step as f64)),
                                ("rows", Json::Num((j - i) as f64)),
                            ],
                        );
                        i = j;
                    }
                    for (bstep, cfg) in &res.boundaries {
                        t.emit(
                            "boundary",
                            &[
                                ("run", Json::Str(plan.name().to_string())),
                                ("step", Json::Num(*bstep as f64)),
                                ("to", Json::Str(cfg.clone())),
                            ],
                        );
                    }
                    t.emit(
                        "run_finish",
                        &[
                            ("run", Json::Str(plan.name().to_string())),
                            ("final_val_loss", Json::Num(res.final_val_loss as f64)),
                        ],
                    );
                }
            }
            println!(
                "\ngrown: {steps} steps ({tau} at {small} + {} at {large}) vs scratch: \
                 {scratch_steps} steps at {large} (FLOP-matched)",
                steps - tau
            );
            let verdict =
                diag::curse_verdict(&outcome.results[0].layer_stats, &outcome.results[1].layer_stats)?;
            println!("{verdict}");
            Ok(())
        }
        "sweep" => {
            let engine = Engine::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let trainer = Trainer::new(&engine, &manifest, &corpus);
            let small = positional(&args, 0, "sweep <small> <large>")?.to_string();
            let large = positional(&args, 1, "sweep <small> <large>")?.to_string();
            let taus: Vec<usize> = args
                .get_str("taus", "0.3,0.6")
                .split(',')
                .filter_map(|s| s.trim().parse::<f64>().ok())
                .map(|f| tau_from_frac(steps, f))
                .collect();
            let strategies: Vec<&str> = args.get_str("strategies", "random,zero").split(',').collect();
            let base = expand_from(&args)?;
            let transfer = transfer_from(&args)?;
            let workers = workers_from(&args)?;
            let mut labels = Vec::new();
            let mut plans = Vec::new();
            for &tau in &taus {
                for sname in &strategies {
                    let plan = RunBuilder::progressive(
                        format!("sweep-{small}-{large}-t{tau}-{sname}"),
                        &small,
                        &large,
                        tau.max(1),
                        steps,
                        schedule_from(&args),
                        ExpandSpec { strategy: strategy_from_name(sname)?, ..base },
                    )
                    .seed(seed)
                    .transfer(transfer)
                    .build()?;
                    labels.push((tau, sname.to_string()));
                    plans.push(plan);
                }
            }
            // Pre-flight vet before the store opens: a rejected grid leaves
            // zero store writes behind (DESIGN.md §13).
            deep_progressive::audit::vet::gate(&plans, Some(&manifest), "sweep")?;
            let mut sweep = Sweep::new(trainer);
            if args.has("progress") {
                sweep.progress(ProgressSink::stderr());
            }
            if let Some(dir) = args.get("store-dir") {
                // Durable sweep: completed runs + trunk snapshots persist in
                // the store; an interrupted invocation resumes from it.
                sweep.store(dir)?;
            }
            for plan in plans {
                sweep.add(plan);
            }
            let outcome = sweep.run_parallel(workers)?;
            for ((tau, sname), res) in labels.iter().zip(&outcome.results) {
                res.curve.write_csv(std::path::Path::new(&out))?;
                println!(
                    "τ={tau:<6} {sname:<14} final val loss {:.4} | {:.2e} FLOPs",
                    res.final_val_loss, res.ledger.total
                );
            }
            println!(
                "executed {:.2e} FLOPs over {workers} worker{}; shared source training saved {:.2e} FLOPs",
                outcome.executed_flops,
                if workers == 1 { "" } else { "s" },
                outcome.shared_flops
            );
            Ok(())
        }
        "ladder" => {
            // Multi-round depth-ladder growth (e.g. l0 → l1 → l3 → l6):
            // boundaries from --taus fractions, or probe-driven placement
            // (--probe: the §7 recipe per round via recipe::LadderController).
            const USAGE: &str = "ladder <cfg0> <cfg1> [<cfg2> ...] \
                                 [--taus F,F,..|--probe] [--strategies a,b] [--rewarm N]";
            let engine = Engine::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let trainer = Trainer::new(&engine, &manifest, &corpus);
            let rungs: Vec<&str> = args.positional.iter().map(|s| s.as_str()).collect();
            if rungs.len() < 2 {
                anyhow::bail!("a ladder needs at least two configs — usage: {USAGE}");
            }
            let n_rounds = rungs.len() - 1;
            let sched = schedule_from(&args);
            let workers = workers_from(&args)?;
            let name = format!("ladder-{}", rungs.join("-"));

            let plans: Vec<RunPlan> = if args.has("probe") {
                let ctl = recipe::LadderController::new(
                    args.get_usize("probe-steps", steps),
                    args.get_f32("tol", 0.04),
                )
                .rewarm(args.get_usize("rewarm", 0))
                .workers(workers);
                let outcome = ctl.plan(&trainer, &name, &rungs, steps, sched, expand_from(&args)?)?;
                for (i, (probe, tau)) in outcome.probes.iter().zip(&outcome.taus).enumerate() {
                    println!(
                        "round {}: {} -> {}: t_mix {:?} tokens ({:?} steps) => expand at step {tau}",
                        i + 1,
                        rungs[i],
                        rungs[i + 1],
                        probe.t_mix_tokens,
                        probe.t_mix_steps,
                    );
                }
                // Re-apply the launcher's cadence/seed knobs to the
                // controller's rounds (its plan keeps builder defaults).
                let plans = vec![apply_eval_every(
                    RunBuilder::ladder(name.as_str(), rungs[0], &outcome.rounds, steps, sched)
                        .seed(seed)
                        .transfer(transfer_from(&args)?),
                    &args,
                )
                .build()?];
                // Probe-driven placement gets the stronger vet: each τ is
                // cross-checked against its round's measured t_mix.
                let t_mix: Vec<Option<usize>> =
                    outcome.probes.iter().map(|p| p.t_mix_steps).collect();
                let ctx = deep_progressive::audit::vet::VetContext {
                    manifest: Some(&manifest),
                    t_mix_steps: Some(&t_mix),
                    waive: &[],
                };
                deep_progressive::audit::vet::gate_with(&plans, &ctx, "ladder")?;
                plans
            } else {
                let plans = ladder_grid(&args, &rungs, steps, seed, sched, USAGE)?;
                deep_progressive::audit::vet::gate(&plans, Some(&manifest), "ladder")?;
                plans
            };

            // Run through the sweep machinery so --workers and --store-dir
            // behave exactly like sweep/bench grids (bit-identical at any
            // worker count; warm stores serve the run without training).
            let mut sweep = Sweep::new(trainer);
            if args.has("progress") {
                sweep.progress(ProgressSink::stderr());
            }
            if let Some(dir) = args.get("store-dir") {
                sweep.store(dir)?;
            }
            for p in &plans {
                sweep.add(p.clone());
            }
            let outcome = sweep.run_parallel(workers)?;
            let fixed_flops = trainer.fixed_flops(rungs[n_rounds], steps)?;
            for (plan, res) in plans.iter().zip(&outcome.results) {
                res.curve.write_csv(std::path::Path::new(&out))?;
                let boundaries: Vec<usize> = (1..=plan.n_boundaries())
                    .filter_map(|d| plan.boundary_at(d))
                    .collect();
                println!(
                    "ladder {} ({} rounds at {:?}): final val loss {:.4} | {:.2e} FLOPs ({:.0}% saving vs fixed-depth {})",
                    plan.name(),
                    n_rounds,
                    boundaries,
                    res.final_val_loss,
                    res.ledger.total,
                    (1.0 - res.ledger.total / fixed_flops) * 100.0,
                    rungs[n_rounds],
                );
            }
            Ok(())
        }
        "serve" => {
            // Fabric coordinator: same ladder-grid semantics (and CSV
            // output) as `ladder`, executed over local engine threads plus
            // every `repro worker` that connects (DESIGN.md §9). `--workers
            // 0` (the default) serves remote workers only.
            const USAGE: &str = "serve <cfg0> <cfg1> [<cfg2> ...] --listen ADDR \
                                 [--taus F,F,..] [--strategies a,b] [--workers N] \
                                 [--store-dir D [--resume]]";
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let rungs: Vec<&str> = args.positional.iter().map(|s| s.as_str()).collect();
            if rungs.len() < 2 {
                anyhow::bail!("a ladder needs at least two configs — usage: {USAGE}");
            }
            let listen = args
                .get("listen")
                .ok_or_else(|| anyhow::anyhow!("missing --listen ADDR — usage: {USAGE}"))?;
            let plans = ladder_grid(&args, &rungs, steps, seed, schedule_from(&args), USAGE)?;
            // Vet before listening: an unvetted grid never binds a socket,
            // opens a store, or dispatches a job (DESIGN.md §13).
            deep_progressive::audit::vet::gate(&plans, Some(&manifest), "serve")?;
            let graph = JobGraph::lower(plans)?;
            let server = FabricServer::bind(listen)?;
            println!("fabric coordinator listening on {}", server.local_addr()?);
            let opts = FabricOptions {
                local_workers: args.get_usize("workers", 0),
                progress: args.has("progress").then(ProgressSink::stderr),
                keep_states: false,
                heartbeat_timeout: Duration::from_secs(args.get_u64("heartbeat-timeout", 20)),
                resume: args.has("resume"),
            };
            let mut store = match args.get("store-dir") {
                Some(dir) => {
                    let salt = RunStore::context_salt(&manifest, &corpus);
                    Some(RunStore::open_salted(dir, &salt)?)
                }
                None => None,
            };
            let (outcome, stats) = server.run(&manifest, &corpus, &graph, &opts, store.as_mut())?;
            for (plan, res) in graph.plans().iter().zip(&outcome.results) {
                res.curve.write_csv(std::path::Path::new(&out))?;
                println!(
                    "{:<40} final val loss {:.4} | {:.2e} FLOPs",
                    plan.name(),
                    res.final_val_loss,
                    res.ledger.total
                );
            }
            println!(
                "fabric: {} dispatched ({} local, {} remote, {} reassigned) + {} cached \
                 over {} connection(s); {} worker(s) lost | executed {:.2e} FLOPs",
                stats.dispatched_jobs,
                stats.local_jobs,
                stats.remote_jobs,
                stats.reassigned_jobs,
                stats.cached_jobs,
                stats.connections,
                stats.workers_lost,
                outcome.executed_flops,
            );
            println!(
                "fabric: {} resumed from journal; {} reconnect(s); snapshots: {} shipped \
                 ({} bytes), {} cache-served",
                stats.resumed_jobs,
                stats.workers_reconnected,
                stats.snapshots_shipped,
                stats.snapshot_bytes_shipped,
                stats.snapshots_cache_served,
            );
            if !stats.rtt_micros.is_empty() {
                println!(
                    "fabric: heartbeat RTT p50 {} us, p99 {} us over {} sample(s)",
                    diag::percentile_us(&stats.rtt_micros, 50.0),
                    diag::percentile_us(&stats.rtt_micros, 99.0),
                    stats.rtt_micros.len(),
                );
            }
            if let Some(path) = args.get("stats-json") {
                std::fs::write(path, stats.to_json())
                    .map_err(|e| anyhow::anyhow!("writing --stats-json {path}: {e}"))?;
                println!("fabric stats JSON -> {path}");
            }
            Ok(())
        }
        "worker" => {
            // Fabric worker: engines only — results land in the
            // coordinator's store, never here. The artifacts + corpus must
            // match the coordinator's (the handshake refuses anything else).
            const USAGE: &str = "worker --connect ADDR [--workers N] [--max-jobs K] \
                                 [--retry-max N] [--retry-base MS] [--fault SPEC]";
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let connect = args
                .get("connect")
                .ok_or_else(|| anyhow::anyhow!("missing --connect ADDR — usage: {USAGE}"))?;
            // `--fault` beats the env (explicit over ambient); either way
            // an empty spec means no injection layer at all.
            let fault = match args.get("fault") {
                Some(text) => Some(FaultSpec::parse(text)?),
                None => FaultSpec::from_env()?,
            }
            .filter(|f| !f.is_empty());
            let opts = WorkerOptions {
                workers: args.get_usize("workers", default_workers()),
                progress: args.has("progress").then(ProgressSink::stderr),
                max_jobs: args.get("max-jobs").and_then(|s| s.parse().ok()),
                retry_max: args.get_usize("retry-max", 0),
                retry_base_ms: args.get_u64("retry-base", 250),
                fault,
            };
            let report = run_worker(connect, &manifest, &corpus, &opts)?;
            println!(
                "worker done: {} job(s) executed, {} reconnect(s){}",
                report.jobs_executed,
                report.reconnects,
                if report.defected { " (defected at --max-jobs)" } else { "" }
            );
            Ok(())
        }
        "chaos" => {
            // Deterministic fault-injection drill (DESIGN.md §10): every
            // fault kind the faultline can inject, each scenario an
            // in-process fleet over loopback, each required to end in a
            // bit-identical outcome or a loud error — never a hang.
            const USAGE: &str = "chaos <cfg0> <cfg1> [<cfg2> ...] [--strategies a,b] \
                                 [--steps N] [--timeout SECS]";
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let rungs: Vec<&str> = args.positional.iter().map(|s| s.as_str()).collect();
            if rungs.len() < 2 {
                anyhow::bail!("a ladder needs at least two configs — usage: {USAGE}");
            }
            let plans = ladder_grid(&args, &rungs, steps, seed, schedule_from(&args), USAGE)?;
            deep_progressive::audit::vet::gate(&plans, Some(&manifest), "chaos")?;
            let timeout = Duration::from_secs(args.get_u64("timeout", 120));
            run_chaos(&manifest, &corpus, &plans, timeout)
        }
        "store" => {
            const USAGE: &str = "store gc --store-dir D [--dry-run] [--keep N]";
            let sub = positional(&args, 0, USAGE)?;
            if sub != "gc" {
                anyhow::bail!("unknown store subcommand '{sub}' — usage: {USAGE}");
            }
            let dir = args
                .get("store-dir")
                .ok_or_else(|| anyhow::anyhow!("missing --store-dir D — usage: {USAGE}"))?;
            let dry_run = args.has("dry-run");
            let keep = args.get_usize("keep", 1);
            // A repository is either a bare store (journal at the root) or
            // a shared one holding per-context `ctx-*` stores; GC each.
            let root = std::path::Path::new(dir);
            let mut roots = Vec::new();
            if root.join("journal.log").is_file() {
                roots.push(root.to_path_buf());
            }
            if let Ok(rd) = std::fs::read_dir(root) {
                for e in rd.flatten() {
                    let p = e.path();
                    let ctx = e.file_name().to_string_lossy().starts_with("ctx-");
                    if ctx && p.join("journal.log").is_file() {
                        roots.push(p);
                    }
                }
            }
            if roots.is_empty() {
                anyhow::bail!(
                    "no run store under '{dir}' (expected journal.log or ctx-*/journal.log)"
                );
            }
            roots.sort();
            for p in roots {
                let mut store = RunStore::open(&p)?;
                let r = store.gc(dry_run, keep)?;
                println!(
                    "{}{}: collected {} run(s) + {} trunk(s), {} bytes; live: {} run(s), {} trunk(s)",
                    if dry_run { "[dry-run] " } else { "" },
                    p.display(),
                    r.collected_runs.len(),
                    r.collected_trunks.len(),
                    r.bytes_reclaimed,
                    r.live_runs,
                    r.live_trunks,
                );
            }
            Ok(())
        }
        "probe-mixing" => {
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let small = positional(&args, 0, "probe-mixing <small> <large>")?.to_string();
            let large = positional(&args, 1, "probe-mixing <small> <large>")?.to_string();
            let probe_steps = args.get_usize("probe-steps", steps);
            let production = args.get_usize("production-steps", steps * 10);
            let workers = workers_from(&args)?;
            // With ≥ 2 workers the probe pair runs as two lockstep jobs on
            // engine-owning threads — identical outcome to the serial path.
            let outcome = if workers >= 2 {
                recipe::probe_mixing_time_parallel(
                    &manifest,
                    &corpus,
                    &small,
                    &large,
                    probe_steps,
                    production,
                    schedule_from(&args),
                    expand_from(&args)?,
                    args.get_f32("tol", 0.04),
                )?
            } else {
                let engine = Engine::cpu()?;
                let trainer = Trainer::new(&engine, &manifest, &corpus);
                recipe::probe_mixing_time(
                    &trainer,
                    &small,
                    &large,
                    probe_steps,
                    production,
                    schedule_from(&args),
                    expand_from(&args)?,
                    args.get_f32("tol", 0.04),
                )?
            };
            println!("{outcome:?}");
            Ok(())
        }
        "convex" => {
            let dim = args.get_usize("dim", 32);
            let p = ConvexProblem::new(dim, dim * 4, seed);
            let total = args.get_usize("steps", 800);
            let tau = tau_from_frac(total, args.get_f64("tau-frac", 0.8));
            let sched = schedule_from(&args);
            let (fixed, prog) = simulate(&p, dim / 2, sched, tau, total, Teleport::Zero, seed);
            println!("fixed:       loss {:.5}  bound {:.5}", fixed.final_loss, fixed.bound);
            println!("progressive: loss {:.5}  bound {:.5}", prog.final_loss, prog.bound);
            Ok(())
        }
        "expand-ckpt" => {
            // Offline expansion of a checkpoint (library checkpoint format).
            const USAGE: &str = "expand-ckpt <src> <dst> --in a.ckpt --out-ckpt b.ckpt";
            let manifest = Manifest::load(&artifacts)?;
            let src_id = positional(&args, 0, USAGE)?.to_string();
            let dst_id = positional(&args, 1, USAGE)?.to_string();
            let src = manifest.get(&src_id)?;
            let dst = manifest.get(&dst_id)?;
            let input = args.get("in").ok_or_else(|| anyhow::anyhow!("missing --in — usage: {USAGE}"))?;
            let output = args
                .get("out-ckpt")
                .ok_or_else(|| anyhow::anyhow!("missing --out-ckpt — usage: {USAGE}"))?;
            let state = checkpoint::load(std::path::Path::new(input), src)?;
            let big = deep_progressive::expansion::expand(src, dst, &state, &expand_from(&args)?)?;
            checkpoint::save(std::path::Path::new(output), &dst_id, &big, dst)?;
            println!("expanded {src_id} -> {dst_id}");
            Ok(())
        }
        "audit" => {
            use deep_progressive::audit;
            // Default paths work from both the repo root and `rust/`.
            let in_repo_root = std::path::Path::new("rust/src").is_dir();
            let src_dir = args.get("src-dir").map(std::path::PathBuf::from).unwrap_or_else(|| {
                std::path::PathBuf::from(if in_repo_root { "rust/src" } else { "src" })
            });
            let golden_dir = args.get("golden").map(std::path::PathBuf::from).unwrap_or_else(|| {
                std::path::PathBuf::from(if in_repo_root {
                    "rust/tests/golden"
                } else {
                    "tests/golden"
                })
            });
            if args.has("fix-allows") {
                let rewritten = audit::lint::fix_allows_dir(&src_dir)?;
                for (rel, n) in &rewritten {
                    println!("annotated {n} bare allow(s) in {rel}");
                }
                println!("fix-allows: {} file(s) rewritten", rewritten.len());
                return Ok(());
            }
            let any = args.has("lints") || args.has("codecs") || args.has("model-check");
            let opts = audit::AuditOptions {
                src_dir,
                golden_dir,
                lints: !any || args.has("lints"),
                codecs: !any || args.has("codecs"),
                model_check: !any || args.has("model-check"),
                bless: args.has("bless"),
                budget: args.get_usize("budget", 2000),
                sample: args.get_usize("sample", 64),
                seed: args.get_u64("seed", 17),
            };
            let report = audit::run(&opts)?;
            print!("{}", report.render());
            if let Some(path) = args.get("report") {
                std::fs::write(path, report.to_json().to_string() + "\n")?;
            }
            if !report.ok() {
                anyhow::bail!("audit found contract violations (see report above)");
            }
            Ok(())
        }
        "vet" => {
            use deep_progressive::audit::vet;
            // Symbolic pre-flight over a plan grid: no engine, no store, no
            // socket — the same checks every execution entry point gates on,
            // plus warning-severity findings those gates stay silent about.
            const USAGE: &str = "vet <cfg0> <cfg1> [<cfg2> ...] [--taus F,F,..] \
                                 [--strategies a,b] [--transfer fixed|completep] \
                                 [--report PATH] [--waive lint,lint] [--fixtures]";
            if args.has("fixtures") {
                // Seeded-violation corpus: every demonstrable lint planted
                // once; always exits nonzero (CI proves the gate bites).
                let fixtures = vet::violation_fixtures();
                let mut planted = 0usize;
                let mut broken = Vec::new();
                for fx in &fixtures {
                    let ctx = vet::VetContext {
                        t_mix_steps: fx.t_mix_steps.as_deref(),
                        ..Default::default()
                    };
                    let report = vet::vet_plans(&fx.plans, &ctx)?;
                    let hits =
                        report.findings.iter().filter(|f| f.lint == fx.lint).count();
                    println!(
                        "fixture {:<22} {} ({} finding(s) for its lint)",
                        fx.lint,
                        if hits == 1 { "fires" } else { "BROKEN" },
                        hits,
                    );
                    if hits != 1 {
                        broken.push(fx.lint);
                    }
                    planted += report.findings.len();
                }
                if !broken.is_empty() {
                    anyhow::bail!(
                        "vet --fixtures: lint(s) {broken:?} did not fire exactly once on \
                         their planted defect"
                    );
                }
                anyhow::bail!(
                    "vet --fixtures: {} finding(s) across {} planted-defect grids — \
                     nonzero exit by design",
                    planted,
                    fixtures.len(),
                );
            }
            let rungs: Vec<&str> = args.positional.iter().map(|s| s.as_str()).collect();
            if rungs.len() < 2 {
                anyhow::bail!("vet needs at least two configs — usage: {USAGE}");
            }
            let plans = ladder_grid(&args, &rungs, steps, seed, schedule_from(&args), USAGE)?;
            // The manifest is optional here: vet is symbolic, so it degrades
            // to depth-suffix parsing when no artifacts are on disk.
            let manifest = Manifest::load(&artifacts).ok();
            let waive: Vec<String> = args
                .get("waive")
                .map(|l| l.split(',').map(|s| s.trim().to_string()).collect())
                .unwrap_or_default();
            let ctx = vet::VetContext {
                manifest: manifest.as_ref(),
                t_mix_steps: None,
                waive: &waive,
            };
            let report = vet::vet_plans(&plans, &ctx)?;
            print!("{}", report.render());
            if let Some(path) = args.get("report") {
                std::fs::write(path, report.to_json().to_string() + "\n")?;
            }
            if !report.ok() {
                anyhow::bail!("plan vet found contract errors (see report above)");
            }
            Ok(())
        }
        cmd if cmd.starts_with("bench-") => {
            let workers = workers_from(&args)?;
            let store_dir = args.get("store-dir").map(std::path::PathBuf::from);
            let ctx = Ctx::new(&artifacts, &out, steps, seed, workers, store_dir)?;
            run_target(&ctx, &cmd[6..])
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            std::process::exit(2);
        }
    }
}

const HELP: &str = r#"repro — Deep Progressive Training reproduction launcher

USAGE: repro <command> [args]   (flags: --name value or --name=value)

  train <cfg_id>                    fixed-size training run
        [--save-every N --ckpt-dir D]   periodic driver snapshots
        [--resume SNAP]                 resume a paused run bit-exactly
  progressive <small> <large>       zero/one-layer progressive training
        [--spike-sigma S]               flag post-expansion loss jumps above
        [--spike-window W]              S × the rolling stddev of the last W
                                        cadence evals (default: report-only)
  diagnose <small> <large>          depth diagnostics: a grown run vs a
        [--tau N|--tau-frac F]          FLOP-matched from-scratch baseline,
        [--workers N] [--store-dir D]   both probed per layer at every eval;
        [--trace PATH]                  prints depth-profile tables, writes
                                        <run>.layers.csv, and renders the
                                        curse-of-depth verdict; --trace
                                        writes a JSONL span-event trace
  sweep <small> <large>             expansion-variant sweep; source-model
        [--taus F,F] [--strategies a,b] training is shared across variants
        [--workers N] [--progress]      parallel over N engine-owning workers
                                        (default: all cores; bit-identical)
        [--store-dir D]                 durable: completed runs + trunk
                                        snapshots persist; an interrupted
                                        sweep resumes re-running only
                                        unfinished jobs, a warm rerun
                                        executes nothing
  ladder <cfg0> <cfg1> [<cfg2> ..]  multi-round depth-ladder growth (2→6→12→24
        [--taus F,F,..]                 style); boundaries at horizon fractions
        [--probe --probe-steps N]       or probe-driven per round: each τ placed
        [--rewarm N]                    at stable_end − t_mix (Takeaway 6);
        [--workers N] [--store-dir D]   --rewarm re-warms LR after each round
        [--strategies a,b]              a grid: one ladder per strategy
  serve <cfg0> <cfg1> [<cfg2> ..]   fabric coordinator: the same ladder grid,
        --listen HOST:PORT              executed over local engine threads
        [--workers N]                   (--workers, default 0) plus every
        [--taus F,F] [--strategies a,b] `repro worker` that connects; CSVs are
        [--store-dir D]                 bit-identical to the serial ladder's;
        [--heartbeat-timeout SECS]      --store-dir shares one artifact repo;
        [--resume]                      --resume rebuilds scheduler state from
        [--stats-json PATH]             the store journal after a coordinator
                                        crash and dispatches only unfinished
                                        work (fully warm: zero dispatches);
                                        --stats-json writes machine-readable
                                        FabricStats incl. heartbeat RTT
                                        percentiles
  worker --connect HOST:PORT        fabric worker: N engine threads executing
        [--workers N] [--max-jobs K]    jobs for a `repro serve` coordinator;
        [--retry-max N]                 --retry-max/--retry-base: redial a lost
        [--retry-base MS]               coordinator with bounded exponential
        [--fault SPEC]                  backoff + jitter, then re-handshake;
                                        --max-jobs K drops the connection after
                                        K jobs; --fault (or REPRO_FAULT) arms
                                        deterministic fault injection, e.g.
                                        drop-after:4,torn-frame:9,stall:3
  chaos <cfg0> <cfg1> [<cfg2> ..]   fault-injection drill: one in-process
        [--strategies a,b]              fleet per fault kind over loopback;
        [--steps N] [--timeout SECS]    every scenario must end bit-identical
                                        to serial or error loudly — a hang
                                        kills the process (exit 124)
  store gc --store-dir D            collect cache entries no referencing sweep
        [--dry-run] [--keep N]          still needs (liveness = the last N
                                        journaled ref sets; default 1)
  probe-mixing <small> <large>      derive τ from two early-stopped probes (§7);
        [--workers N]                   ≥2 workers run the pair as lockstep jobs
  audit                             contract audit: determinism lints + codec
        [--lints] [--codecs]            golden-vector drift detection + scheduler
        [--model-check]                 order-permutation model check (no switch
        [--bless]                       = all three); --bless re-writes the
        [--fix-allows]                  golden fixtures after an intentional
        [--report PATH]                 codec change; --fix-allows annotates
        [--budget N] [--sample N]       bare #[allow]s; --report writes JSON;
        [--src-dir D] [--golden D]      suppress lints only via inline
                                        `// audit:allow(<lint>): <reason>`
  vet <cfg0> <cfg1> [<cfg2> ..]     symbolic pre-flight over a ladder grid:
        [--taus F,F] [--strategies a,b] schedule shape, expansion timing,
        [--transfer fixed|completep]    init/HP-transfer conformance, grid
        [--report PATH]                 coherence — no engine, store, or
        [--waive lint,lint]             socket; every execution entry point
        [--fixtures]                    gates on the error-severity subset;
                                        --report writes JSON (CI artifact);
                                        --waive downgrades named lints;
                                        --fixtures runs the seeded-violation
                                        corpus and exits nonzero by design
  convex                            §4 convex-theory simulator
  expand-ckpt <src> <dst>           offline checkpoint depth expansion
  bench-fig1 .. bench-fig22         reproduce each paper figure
  bench-table1 bench-table2         reproduce the paper tables
  bench-theory                      §4 bound verification
  bench-perf                        dispatch-overhead benchmark: device-resident
                                    vs host-roundtrip steps/sec (BENCH_perf.json)
  bench-parallel                    pool-scaling benchmark: steps/sec at 1/2/4
                                    workers on a fixed grid (BENCH_parallel.json)
  bench-fabric                      fabric benchmark: the same grid serial vs 1/2
                                    loopback worker connections (BENCH_fabric.json)
  bench-ladder                      FLOP-matched ladder vs one-shot expansion vs
                                    fixed-depth comparison (BENCH_ladder.json)
  bench-all                         everything (grids honor --workers)
  list | list-benches | inspect <cfg_id>

COMMON FLAGS
  --steps N          horizon (default 240; figures scale internally)
  --lr F --sched wsd|cosine|constant --decay-frac F
  --strategy random|copying|copying_inter|copying_last|zero|zero_n|zero_l
  --insertion bottom|top   --os inherit|copy|reset
  --tau N | --tau-frac F   --seed N   --eval-every N   --progress
  --transfer fixed|completep   HP-transfer rule stamped on grid plans
                     (arXiv:2505.01618; vet rejects grids mixing rules)
  --workers N        pool size for sweep/bench grids (default: all cores)
  --store-dir D      durable run cache for sweep/bench grids (crash-safe
                     journal; repeated invocations skip completed work)
  --artifacts DIR (default artifacts)   --out DIR (default results)
"#;

#[cfg(test)]
mod tests {
    use super::*;

    fn parsed(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn workers_zero_is_a_friendly_error_not_a_silent_serial_run() {
        let err = workers_from(&parsed("sweep --workers 0")).unwrap_err();
        assert!(format!("{err:#}").contains("at least 1"), "{err:#}");
        let err = workers_from(&parsed("ladder --workers nope")).unwrap_err();
        assert!(format!("{err:#}").contains("positive number"), "{err:#}");
        assert_eq!(workers_from(&parsed("sweep --workers 3")).unwrap(), 3);
        assert!(workers_from(&parsed("sweep")).unwrap() >= 1);
    }

    #[test]
    fn serve_ladder_worker_store_have_flag_vocabularies() {
        for cmd in ["serve", "worker", "store", "ladder", "sweep", "chaos", "diagnose"] {
            assert!(spec_for(cmd).is_some(), "{cmd} lost its CommandSpec");
        }
        // The hardened parse rejects typos on the new commands too.
        let spec = spec_for("serve").unwrap();
        let argv = "serve a b --lsten 1.2.3.4:5".split_whitespace().map(String::from);
        let err = Args::parse_for(argv, &spec).unwrap_err();
        assert!(err.contains("unknown flag --lsten"), "{err}");
        // The resilience/fault knobs parse on their commands.
        let spec = spec_for("worker").unwrap();
        let argv = "worker --connect h:1 --retry-max 5 --retry-base 100 --fault drop-after:4"
            .split_whitespace()
            .map(String::from);
        assert!(Args::parse_for(argv, &spec).is_ok());
        let spec = spec_for("serve").unwrap();
        let argv = "serve a b --listen h:1 --store-dir d --resume --stats-json s.json"
            .split_whitespace()
            .map(String::from);
        assert!(Args::parse_for(argv, &spec).is_ok());
        // The diagnose vocabulary parses its own knobs and rejects typos.
        let spec = spec_for("diagnose").unwrap();
        let argv = "diagnose a b --tau-frac 0.5 --workers 2 --store-dir d --trace t.jsonl"
            .split_whitespace()
            .map(String::from);
        assert!(Args::parse_for(argv, &spec).is_ok());
        let argv = "diagnose a b --trce t.jsonl".split_whitespace().map(String::from);
        assert!(Args::parse_for(argv, &spec).unwrap_err().contains("unknown flag --trce"));
    }

    #[test]
    fn vet_has_a_flag_vocabulary_and_transfer_parses_everywhere() {
        let spec = spec_for("vet").unwrap();
        let argv = "vet a b --taus 0.3,0.6 --strategies random,zero --transfer completep \
                    --report r.json --waive zero-init --fixtures"
            .split_whitespace()
            .map(String::from);
        assert!(Args::parse_for(argv, &spec).is_ok());
        let argv = "vet a b --wave zero-init".split_whitespace().map(String::from);
        assert!(Args::parse_for(argv, &spec).unwrap_err().contains("unknown flag --wave"));
        // `--transfer` is part of every grid-launching vocabulary.
        for cmd in ["sweep", "ladder", "serve", "chaos"] {
            let spec = spec_for(cmd).unwrap();
            let argv = format!("{cmd} a b --transfer fixed");
            assert!(
                Args::parse_for(argv.split_whitespace().map(String::from), &spec).is_ok(),
                "{cmd} rejects --transfer"
            );
        }
        assert!(transfer_from(&parsed("vet a b --transfer completep")).is_ok());
        let err = transfer_from(&parsed("vet a b --transfer nope")).unwrap_err();
        assert!(format!("{err:#}").contains("unknown transfer rule"), "{err:#}");
    }

    #[test]
    fn spike_flags_configure_the_detector_with_contextual_errors() {
        // Defaults: absolute report-only detector, no flags required.
        assert!(spike_detector_from(&parsed("progressive a b")).is_ok());
        assert!(spike_detector_from(&parsed("progressive a b --spike-sigma 2.5")).is_ok());
        assert!(spike_detector_from(
            &parsed("progressive a b --spike-sigma 2.5 --spike-window 6")
        )
        .is_ok());

        let err =
            spike_detector_from(&parsed("progressive a b --spike-window 6")).unwrap_err();
        assert!(format!("{err:#}").contains("only makes sense with --spike-sigma"), "{err:#}");
        let err =
            spike_detector_from(&parsed("progressive a b --spike-sigma nope")).unwrap_err();
        assert!(format!("{err:#}").contains("positive number"), "{err:#}");
        let err =
            spike_detector_from(&parsed("progressive a b --spike-sigma -1")).unwrap_err();
        assert!(format!("{err:#}").contains("positive number"), "{err:#}");
        let err = spike_detector_from(&parsed("progressive a b --spike-sigma 2 --spike-window 1"))
            .unwrap_err();
        assert!(format!("{err:#}").contains("at least"), "{err:#}");
    }
}
