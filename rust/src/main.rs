//! `repro` — launcher for the Deep Progressive Training reproduction.
//!
//! Commands:
//!   train <cfg_id> [--steps N] [--sched wsd|cosine|constant] [--lr F]
//!         [--seed N]                                fixed-size training
//!   progressive <small> <large> [--tau N|--tau-frac F] [--steps N] ...
//!         [--strategy random|copying|zero|zero_n|zero_l] [--insertion top|bottom]
//!   probe-mixing <small> <large> [--probe-steps N] [--steps N]
//!         the paper's §7 recipe step 4: derive τ from two early-stopped runs
//!   convex [--dim N] [--tau-frac F]                 §4 theory simulator
//!   bench-<target>  (fig1..fig22, table1, table2, theory, all)
//!   list / list-benches / inspect <cfg_id>
//!
//! Python never runs here: artifacts are AOT'd once by `make artifacts`.

use anyhow::Result;
use deep_progressive::bench::{run_target, Ctx, ALL_TARGETS};
use deep_progressive::checkpoint;
use deep_progressive::cli::Args;
use deep_progressive::convex::{simulate, ConvexProblem, Teleport};
use deep_progressive::coordinator::{recipe, RunSpec, Trainer};
use deep_progressive::data::{Corpus, CorpusConfig};
use deep_progressive::expansion::{CopyOrder, ExpandSpec, Insertion, Strategy};
use deep_progressive::runtime::{Engine, Manifest};
use deep_progressive::schedule::Schedule;

fn schedule_from(args: &Args) -> Schedule {
    let lr = args.get_f32("lr", 0.01);
    match args.get_str("sched", "wsd") {
        "cosine" => Schedule::cosine(lr),
        "constant" => Schedule::Constant { peak: lr, warmup_frac: 0.02 },
        "linear" => Schedule::Linear { peak: lr, warmup_frac: 0.02 },
        _ => Schedule::Wsd { peak: lr, warmup_frac: 0.02, decay_frac: args.get_f32("decay-frac", 0.2) },
    }
}

fn expand_from(args: &Args) -> ExpandSpec {
    let strategy = match args.get_str("strategy", "random") {
        "copying" | "copying_stack" => Strategy::Copying(CopyOrder::Stack),
        "copying_inter" => Strategy::Copying(CopyOrder::Inter),
        "copying_last" => Strategy::Copying(CopyOrder::Last),
        "zero" => Strategy::Zero,
        "zero_n" | "copying_zero_n" => Strategy::CopyingZeroN,
        "zero_l" | "copying_zero_l" => Strategy::CopyingZeroL,
        _ => Strategy::Random,
    };
    ExpandSpec {
        strategy,
        insertion: if args.get_str("insertion", "bottom") == "top" { Insertion::Top } else { Insertion::Bottom },
        os_policy: match args.get_str("os", "inherit") {
            "copy" => deep_progressive::expansion::OsPolicy::Copy,
            "reset" => deep_progressive::expansion::OsPolicy::Reset,
            _ => deep_progressive::expansion::OsPolicy::Inherit,
        },
        seed: args.get_u64("expand-seed", 7),
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_str("artifacts", "artifacts").to_string();
    let out = args.get_str("out", "results").to_string();
    let steps = args.get_usize("steps", 240);
    let seed = args.get_u64("seed", 17);

    match args.command.as_str() {
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        "list" => {
            let m = Manifest::load(&artifacts)?;
            for (id, c) in &m.configs {
                println!(
                    "{id:24} {} n_layer={:<3} params={:<9} active={:<9} artifacts={:?}",
                    c.model.family,
                    c.model.n_layer,
                    c.param_count,
                    c.active_param_count,
                    c.artifacts.keys().collect::<Vec<_>>()
                );
            }
            Ok(())
        }
        "list-benches" => {
            for t in ALL_TARGETS {
                println!("bench-{t}");
            }
            Ok(())
        }
        "inspect" => {
            let m = Manifest::load(&artifacts)?;
            let c = m.get(&args.positional[0])?;
            println!("config {}: {} params, {} active", c.cfg_id, c.param_count, c.active_param_count);
            for p in &c.params {
                println!("  {:32} {:?} init={:?} muon={}", p.name, p.shape, p.init, p.muon);
            }
            Ok(())
        }
        "train" => {
            let engine = Engine::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let trainer = Trainer::new(&engine, &manifest, &corpus);
            let cfg_id = args.positional.first().expect("usage: train <cfg_id>").clone();
            let mut spec = RunSpec::fixed(format!("train-{cfg_id}"), &cfg_id, steps, schedule_from(&args));
            spec.seed = seed;
            let res = trainer.run(&spec)?;
            res.curve.write_csv(std::path::Path::new(&out))?;
            println!(
                "final val loss {:.4} | {:.2e} FLOPs | {} tokens | entropy floor {:.3}",
                res.final_val_loss, res.ledger.total, res.ledger.tokens, corpus.entropy_floor
            );
            Ok(())
        }
        "progressive" => {
            let engine = Engine::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let trainer = Trainer::new(&engine, &manifest, &corpus);
            let small = args.positional.first().expect("usage: progressive <small> <large>").clone();
            let large = args.positional.get(1).expect("usage: progressive <small> <large>").clone();
            let tau = args
                .get("tau")
                .and_then(|s| s.parse().ok())
                .unwrap_or(((steps as f32) * args.get_f32("tau-frac", 0.8)) as usize);
            let mut spec = RunSpec::progressive(
                format!("prog-{small}-{large}"),
                &small,
                &large,
                tau,
                steps,
                schedule_from(&args),
                expand_from(&args),
            );
            spec.seed = seed;
            let res = trainer.run(&spec)?;
            res.curve.write_csv(std::path::Path::new(&out))?;
            let fixed_flops = trainer.fixed_flops(&large, steps)?;
            println!(
                "final val loss {:.4} | {:.2e} FLOPs ({:.0}% saving vs fixed) | expansion at step {tau}",
                res.final_val_loss,
                res.ledger.total,
                (1.0 - res.ledger.total / fixed_flops) * 100.0
            );
            Ok(())
        }
        "probe-mixing" => {
            let engine = Engine::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            let corpus = Corpus::generate(CorpusConfig::default());
            let trainer = Trainer::new(&engine, &manifest, &corpus);
            let small = args.positional.first().expect("usage: probe-mixing <small> <large>").clone();
            let large = args.positional.get(1).expect("usage: probe-mixing <small> <large>").clone();
            let probe_steps = args.get_usize("probe-steps", steps);
            let production = args.get_usize("production-steps", steps * 10);
            let outcome = recipe::probe_mixing_time(
                &trainer,
                &small,
                &large,
                probe_steps,
                production,
                schedule_from(&args),
                expand_from(&args),
                args.get_f32("tol", 0.04),
            )?;
            println!("{outcome:?}");
            Ok(())
        }
        "convex" => {
            let dim = args.get_usize("dim", 32);
            let p = ConvexProblem::new(dim, dim * 4, seed);
            let total = args.get_usize("steps", 800);
            let tau = (total as f32 * args.get_f32("tau-frac", 0.8)) as usize;
            let sched = schedule_from(&args);
            let (fixed, prog) = simulate(&p, dim / 2, sched, tau, total, Teleport::Zero, seed);
            println!("fixed:       loss {:.5}  bound {:.5}", fixed.final_loss, fixed.bound);
            println!("progressive: loss {:.5}  bound {:.5}", prog.final_loss, prog.bound);
            Ok(())
        }
        "expand-ckpt" => {
            // Offline expansion of a checkpoint (library checkpoint format).
            let manifest = Manifest::load(&artifacts)?;
            let src_id = args.positional.first().expect("usage: expand-ckpt <src> <dst> --in a --out-ckpt b").clone();
            let dst_id = args.positional.get(1).expect("usage: expand-ckpt <src> <dst>").clone();
            let src = manifest.get(&src_id)?;
            let dst = manifest.get(&dst_id)?;
            let state = checkpoint::load(std::path::Path::new(args.get("in").expect("--in")), src)?;
            let big = deep_progressive::expansion::expand(src, dst, &state, &expand_from(&args))?;
            checkpoint::save(std::path::Path::new(args.get("out-ckpt").expect("--out-ckpt")), &dst_id, &big, dst)?;
            println!("expanded {src_id} -> {dst_id}");
            Ok(())
        }
        cmd if cmd.starts_with("bench-") => {
            let ctx = Ctx::new(&artifacts, &out, steps, seed)?;
            run_target(&ctx, &cmd[6..])
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            std::process::exit(2);
        }
    }
}

const HELP: &str = r#"repro — Deep Progressive Training reproduction launcher

USAGE: repro <command> [args]

  train <cfg_id>                    fixed-size training run
  progressive <small> <large>       zero/one-layer progressive training
  probe-mixing <small> <large>      derive τ from two early-stopped probes (§7)
  convex                            §4 convex-theory simulator
  expand-ckpt <src> <dst>           offline checkpoint depth expansion
  bench-fig1 .. bench-fig22         reproduce each paper figure
  bench-table1 bench-table2         reproduce the paper tables
  bench-theory                      §4 bound verification
  bench-all                         everything
  list | list-benches | inspect <cfg_id>

COMMON FLAGS
  --steps N          horizon (default 240; figures scale internally)
  --lr F --sched wsd|cosine|constant --decay-frac F
  --strategy random|copying|copying_inter|copying_last|zero|zero_n|zero_l
  --insertion bottom|top   --os inherit|copy|reset
  --tau N | --tau-frac F   --seed N
  --artifacts DIR (default artifacts)   --out DIR (default results)
"#;
