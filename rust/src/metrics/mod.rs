//! Run metrics: loss curves keyed by (step, tokens, flops), CSV/JSONL
//! writers, and the mixing detector.
//!
//! "Mixing" (§5) is the paper's central observable: the progressive run's
//! loss curve merging into the fixed-size run's. The detector compares two
//! curves on a common x-axis (tokens — §C.4 shows mixing is data-, not
//! iteration-counted) and reports the first point after which the gap stays
//! within tolerance.

use std::fmt::Write as _;
use std::path::Path;

/// One logged evaluation point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    pub step: usize,
    pub tokens: u64,
    pub flops: f64,
    pub train_loss: f32,
    pub val_loss: f32,
    pub lr: f32,
}

/// A named loss curve (one run).
#[derive(Debug, Clone, Default)]
pub struct Curve {
    pub name: String,
    pub points: Vec<CurvePoint>,
}

impl Curve {
    pub fn new(name: impl Into<String>) -> Curve {
        Curve { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    pub fn final_val_loss(&self) -> Option<f32> {
        self.points.last().map(|p| p.val_loss)
    }

    /// Linear interpolation of val loss at a token count. `None` outside the
    /// curve's token domain `[first, last]` — **no extrapolation** in either
    /// direction. (This used to fall through to `pts.last()` past the last
    /// point; the mixing detector then compared progressive eval points
    /// against a flat-extrapolated fixed value the fixed run never produced,
    /// faking or masking mixing — see [`mixing_point`].)
    pub fn val_at_tokens(&self, tokens: u64) -> Option<f32> {
        let pts = &self.points;
        let (first, last) = (pts.first()?, pts.last()?);
        if tokens < first.tokens || tokens > last.tokens {
            return None;
        }
        for w in pts.windows(2) {
            if (w[0].tokens..=w[1].tokens).contains(&tokens) {
                let span = (w[1].tokens - w[0].tokens).max(1) as f32;
                let t = (tokens - w[0].tokens) as f32 / span;
                return Some(w[0].val_loss * (1.0 - t) + w[1].val_loss * t);
            }
        }
        // Single-point curve: tokens == the one point's token count.
        Some(last.val_loss)
    }

    /// CSV serialization with **round-trip-exact** float formatting: `{}`
    /// (shortest representation that parses back to the identical bits), not
    /// a fixed precision. A `{:.6}` loss column made any CSV diff blind to
    /// sub-1e-6 divergence — the CI store-resume smoke diffs these files to
    /// certify bit-identity, so truncation there was a hole in the
    /// determinism contract (pinned by `csv_is_bit_exact_to_one_ulp`).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,tokens,flops,train_loss,val_loss,lr\n");
        for p in &self.points {
            let _ = writeln!(
                s,
                "{},{},{},{},{},{}",
                p.step, p.tokens, p.flops, p.train_loss, p.val_loss, p.lr
            );
        }
        s
    }

    pub fn write_csv(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.csv", self.name)), self.to_csv())
    }
}

/// Mixing detector (§5): first token count after which
/// |progressive − fixed| / fixed ≤ `rel_tol` for `holdout` consecutive
/// progressive eval points through the end of the overlap.
///
/// Only the **true overlap** of the two curves is evaluated: progressive
/// points outside the fixed curve's token domain neither confirm nor reset
/// the detector. Before this restriction (and [`Curve::val_at_tokens`]'s
/// no-extrapolation fix) a progressive curve that outlived the fixed one was
/// compared against the fixed curve's frozen final value — which can fake a
/// mixing point past the real overlap (false positive: the progressive run
/// keeps improving and eventually "meets" the stale constant) and, because
/// the out-of-domain points read as failures, could also reset an
/// in-tolerance run established inside the overlap (false negative). Both
/// cases corrupt the `suggested_tau` the §7 recipe derives from this value.
pub fn mixing_point(progressive: &Curve, fixed: &Curve, rel_tol: f32, holdout: usize) -> Option<u64> {
    let mut run = 0usize;
    let mut candidate: Option<u64> = None;
    for p in &progressive.points {
        let Some(f) = fixed.val_at_tokens(p.tokens) else {
            continue; // outside the overlap: ignored, not a failure
        };
        if (p.val_loss - f).abs() / f.max(1e-6) <= rel_tol {
            if run == 0 {
                candidate = Some(p.tokens);
            }
            run += 1;
        } else {
            run = 0;
            candidate = None;
        }
    }
    if run >= holdout.max(1) {
        candidate
    } else {
        None
    }
}

/// Monotone helper: once mixed at the end, mixing_point is stable under
/// appending more in-tolerance points (invariant under test + proptest).
pub fn is_mixed(progressive: &Curve, fixed: &Curve, rel_tol: f32, holdout: usize) -> bool {
    mixing_point(progressive, fixed, rel_tol, holdout).is_some()
}

/// Markdown table writer for bench outputs (the "paper rows" printer).
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], w: &[usize], out: &mut String| {
            out.push('|');
            for (c, width) in cells.iter().zip(w) {
                let _ = write!(out, " {c:width$} |");
            }
            out.push('\n');
        };
        line(&self.header, &w, &mut out);
        out.push('|');
        for width in &w {
            let _ = write!(out, "{:-<1$}|", "", width + 2);
        }
        out.push('\n');
        for r in &self.rows {
            line(r, &w, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(name: &str, vals: &[(u64, f32)]) -> Curve {
        let mut c = Curve::new(name);
        for (i, &(tokens, v)) in vals.iter().enumerate() {
            c.push(CurvePoint { step: i, tokens, flops: 0.0, train_loss: v, val_loss: v, lr: 0.01 });
        }
        c
    }

    #[test]
    fn interpolation() {
        let c = curve("a", &[(0, 4.0), (100, 2.0)]);
        assert_eq!(c.val_at_tokens(50), Some(3.0));
        assert_eq!(c.val_at_tokens(100), Some(2.0));
    }

    #[test]
    fn detects_mixing() {
        let fixed = curve("f", &[(0, 4.0), (100, 3.0), (200, 2.5), (300, 2.2), (400, 2.0)]);
        let prog = curve("p", &[(0, 6.0), (100, 4.0), (200, 2.55), (300, 2.21), (400, 2.01)]);
        let m = mixing_point(&prog, &fixed, 0.03, 2).unwrap();
        assert_eq!(m, 200);
    }

    #[test]
    fn no_mixing_when_gap_persists() {
        let fixed = curve("f", &[(0, 4.0), (200, 2.5), (400, 2.0)]);
        let prog = curve("p", &[(0, 6.0), (200, 3.5), (400, 3.0)]);
        assert!(mixing_point(&prog, &fixed, 0.03, 2).is_none());
    }

    #[test]
    fn unmixing_resets_detector() {
        // Dips into tolerance then leaves again: not mixed.
        let fixed = curve("f", &[(0, 4.0), (100, 3.0), (200, 2.5), (300, 2.2)]);
        let prog = curve("p", &[(0, 4.0), (100, 3.0), (200, 3.2), (300, 3.4)]);
        assert!(mixing_point(&prog, &fixed, 0.03, 2).is_none());
    }

    #[test]
    fn no_extrapolation_outside_domain() {
        let c = curve("a", &[(100, 4.0), (200, 2.0)]);
        assert_eq!(c.val_at_tokens(99), None, "no extrapolation before the first point");
        assert_eq!(c.val_at_tokens(201), None, "no flat extrapolation past the last point");
        assert_eq!(c.val_at_tokens(100), Some(4.0));
        assert_eq!(c.val_at_tokens(200), Some(2.0));
        // Single-point curve: defined exactly at that point, nowhere else.
        let one = curve("b", &[(50, 3.0)]);
        assert_eq!(one.val_at_tokens(50), Some(3.0));
        assert_eq!(one.val_at_tokens(49), None);
        assert_eq!(one.val_at_tokens(51), None);
        assert_eq!(curve("e", &[]).val_at_tokens(0), None);
    }

    #[test]
    fn overlap_false_positive_regression() {
        // Regression: the progressive probe outlives the fixed one. Under
        // flat extrapolation its tail was compared against the fixed curve's
        // frozen final value (2.5), which it crosses — the old detector
        // reported mixing at 600 even though inside the true overlap
        // (tokens ≤ 400) the gap never closes.
        let fixed = curve("f", &[(0, 4.0), (200, 3.0), (400, 2.5)]);
        let prog = curve(
            "p",
            &[(0, 6.0), (200, 4.0), (400, 3.2), (600, 2.52), (800, 2.49)],
        );
        assert_eq!(
            mixing_point(&prog, &fixed, 0.03, 2),
            None,
            "points past the overlap must not fake mixing against an extrapolated value"
        );
    }

    #[test]
    fn overlap_false_negative_regression() {
        // Regression: mixing established inside the overlap, then the
        // progressive curve keeps improving past the fixed curve's end. The
        // old detector compared those tail points against the stale final
        // value, read them as failures, and reset the in-tolerance run —
        // missing a mixing that genuinely held through the end of the
        // overlap.
        let fixed = curve("f", &[(0, 4.0), (200, 3.0), (400, 2.5)]);
        let prog = curve(
            "p",
            &[(0, 6.0), (200, 3.01), (400, 2.51), (600, 2.0), (800, 1.5)],
        );
        assert_eq!(
            mixing_point(&prog, &fixed, 0.03, 2),
            Some(200),
            "mixing held through the full overlap; the out-of-overlap tail must not reset it"
        );
    }

    #[test]
    fn non_overlapping_curves_never_mix() {
        let fixed = curve("f", &[(0, 3.0), (100, 2.0)]);
        let prog = curve("p", &[(200, 2.0), (300, 2.0)]);
        assert_eq!(mixing_point(&prog, &fixed, 0.5, 1), None);
        assert_eq!(mixing_point(&fixed, &prog, 0.5, 1), None);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["run", "loss"]);
        t.row(vec!["fixed".into(), "2.01".into()]);
        let s = t.render();
        assert!(s.contains("| run   | loss |"));
    }

    #[test]
    fn csv_roundtrip_columns() {
        let c = curve("x", &[(0, 1.0)]);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,tokens,flops,train_loss,val_loss,lr"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn csv_floats_roundtrip_to_identical_bits() {
        // Values chosen to be awkward in decimal: the CSV must parse back to
        // the *identical* f32/f64 bits (shortest round-trip formatting).
        let mut c = Curve::new("x");
        c.push(CurvePoint {
            step: 3,
            tokens: 12_345,
            flops: 6.02e23_f64 / 7.0,
            train_loss: 2.0f32 / 3.0,
            val_loss: f32::from_bits(0x3f9d70a4), // ~1.23: not exactly representable
            lr: 0.01f32 * 0.3,
        });
        let csv = c.to_csv();
        let row = csv.lines().nth(1).unwrap();
        let cols: Vec<&str> = row.split(',').collect();
        assert_eq!(cols.len(), 6);
        assert_eq!(cols[2].parse::<f64>().unwrap().to_bits(), c.points[0].flops.to_bits());
        assert_eq!(cols[3].parse::<f32>().unwrap().to_bits(), c.points[0].train_loss.to_bits());
        assert_eq!(cols[4].parse::<f32>().unwrap().to_bits(), c.points[0].val_loss.to_bits());
        assert_eq!(cols[5].parse::<f32>().unwrap().to_bits(), c.points[0].lr.to_bits());
    }

    #[test]
    fn csv_is_bit_exact_to_one_ulp() {
        // The CI store-resume smoke certifies bit-identity by diffing CSVs;
        // that only works if a 1-ulp loss perturbation changes the text
        // (the old {:.6} formatting rounded it away).
        let base = curve("x", &[(0, 2.3456789), (100, 1.2345678)]);
        let mut bumped = base.clone();
        bumped.points[1].val_loss = f32::from_bits(bumped.points[1].val_loss.to_bits() + 1);
        assert_ne!(
            base.to_csv(),
            bumped.to_csv(),
            "a 1-ulp val-loss perturbation must be visible in the CSV"
        );
        let mut bumped = base.clone();
        bumped.points[0].flops = f64::from_bits(1e9f64.to_bits() + 1);
        let mut reference = base.clone();
        reference.points[0].flops = 1e9;
        assert_ne!(reference.to_csv(), bumped.to_csv(), "1-ulp flops perturbation must be visible");
    }
}
