//! Run metrics: loss curves keyed by (step, tokens, flops), CSV/JSONL
//! writers, and the mixing detector.
//!
//! "Mixing" (§5) is the paper's central observable: the progressive run's
//! loss curve merging into the fixed-size run's. The detector compares two
//! curves on a common x-axis (tokens — §C.4 shows mixing is data-, not
//! iteration-counted) and reports the first point after which the gap stays
//! within tolerance.

use std::fmt::Write as _;
use std::path::Path;

/// One logged evaluation point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    pub step: usize,
    pub tokens: u64,
    pub flops: f64,
    pub train_loss: f32,
    pub val_loss: f32,
    pub lr: f32,
}

/// A named loss curve (one run).
#[derive(Debug, Clone, Default)]
pub struct Curve {
    pub name: String,
    pub points: Vec<CurvePoint>,
}

impl Curve {
    pub fn new(name: impl Into<String>) -> Curve {
        Curve { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    pub fn final_val_loss(&self) -> Option<f32> {
        self.points.last().map(|p| p.val_loss)
    }

    /// Linear interpolation of val loss at a token count.
    pub fn val_at_tokens(&self, tokens: u64) -> Option<f32> {
        let pts = &self.points;
        if pts.is_empty() || tokens < pts[0].tokens {
            return None;
        }
        for w in pts.windows(2) {
            if (w[0].tokens..=w[1].tokens).contains(&tokens) {
                let span = (w[1].tokens - w[0].tokens).max(1) as f32;
                let t = (tokens - w[0].tokens) as f32 / span;
                return Some(w[0].val_loss * (1.0 - t) + w[1].val_loss * t);
            }
        }
        pts.last().map(|p| p.val_loss)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,tokens,flops,train_loss,val_loss,lr\n");
        for p in &self.points {
            let _ = writeln!(
                s,
                "{},{},{:.6e},{:.6},{:.6},{:.6e}",
                p.step, p.tokens, p.flops, p.train_loss, p.val_loss, p.lr
            );
        }
        s
    }

    pub fn write_csv(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.csv", self.name)), self.to_csv())
    }
}

/// Mixing detector (§5): first token count after which
/// |progressive − fixed| / fixed ≤ `rel_tol` for `holdout` consecutive
/// progressive eval points through the end of the overlap.
pub fn mixing_point(progressive: &Curve, fixed: &Curve, rel_tol: f32, holdout: usize) -> Option<u64> {
    let pts = &progressive.points;
    if pts.is_empty() {
        return None;
    }
    let ok = |i: usize| -> bool {
        let p = pts[i];
        match fixed.val_at_tokens(p.tokens) {
            Some(f) => (p.val_loss - f).abs() / f.max(1e-6) <= rel_tol,
            None => false,
        }
    };
    let mut run = 0usize;
    let mut candidate: Option<u64> = None;
    for i in 0..pts.len() {
        if ok(i) {
            if run == 0 {
                candidate = Some(pts[i].tokens);
            }
            run += 1;
        } else {
            run = 0;
            candidate = None;
        }
    }
    if run >= holdout {
        candidate
    } else {
        None
    }
}

/// Monotone helper: once mixed at the end, mixing_point is stable under
/// appending more in-tolerance points (invariant under test + proptest).
pub fn is_mixed(progressive: &Curve, fixed: &Curve, rel_tol: f32, holdout: usize) -> bool {
    mixing_point(progressive, fixed, rel_tol, holdout).is_some()
}

/// Markdown table writer for bench outputs (the "paper rows" printer).
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], w: &[usize], out: &mut String| {
            out.push('|');
            for (c, width) in cells.iter().zip(w) {
                let _ = write!(out, " {c:width$} |");
            }
            out.push('\n');
        };
        line(&self.header, &w, &mut out);
        out.push('|');
        for width in &w {
            let _ = write!(out, "{:-<1$}|", "", width + 2);
        }
        out.push('\n');
        for r in &self.rows {
            line(r, &w, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(name: &str, vals: &[(u64, f32)]) -> Curve {
        let mut c = Curve::new(name);
        for (i, &(tokens, v)) in vals.iter().enumerate() {
            c.push(CurvePoint { step: i, tokens, flops: 0.0, train_loss: v, val_loss: v, lr: 0.01 });
        }
        c
    }

    #[test]
    fn interpolation() {
        let c = curve("a", &[(0, 4.0), (100, 2.0)]);
        assert_eq!(c.val_at_tokens(50), Some(3.0));
        assert_eq!(c.val_at_tokens(100), Some(2.0));
    }

    #[test]
    fn detects_mixing() {
        let fixed = curve("f", &[(0, 4.0), (100, 3.0), (200, 2.5), (300, 2.2), (400, 2.0)]);
        let prog = curve("p", &[(0, 6.0), (100, 4.0), (200, 2.55), (300, 2.21), (400, 2.01)]);
        let m = mixing_point(&prog, &fixed, 0.03, 2).unwrap();
        assert_eq!(m, 200);
    }

    #[test]
    fn no_mixing_when_gap_persists() {
        let fixed = curve("f", &[(0, 4.0), (200, 2.5), (400, 2.0)]);
        let prog = curve("p", &[(0, 6.0), (200, 3.5), (400, 3.0)]);
        assert!(mixing_point(&prog, &fixed, 0.03, 2).is_none());
    }

    #[test]
    fn unmixing_resets_detector() {
        // Dips into tolerance then leaves again: not mixed.
        let fixed = curve("f", &[(0, 4.0), (100, 3.0), (200, 2.5), (300, 2.2)]);
        let prog = curve("p", &[(0, 4.0), (100, 3.0), (200, 3.2), (300, 3.4)]);
        assert!(mixing_point(&prog, &fixed, 0.03, 2).is_none());
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["run", "loss"]);
        t.row(vec!["fixed".into(), "2.01".into()]);
        let s = t.render();
        assert!(s.contains("| run   | loss |"));
    }

    #[test]
    fn csv_roundtrip_columns() {
        let c = curve("x", &[(0, 1.0)]);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,tokens,flops,train_loss,val_loss,lr"));
        assert_eq!(csv.lines().count(), 2);
    }
}
