//! Device-resident model state and per-stage executable bindings.
//!
//! Pre-refactor, every dispatch serialized the full parameter + optimizer
//! state from host `Vec<f32>`s into fresh literals, uploaded them, then
//! re-materialized the whole output tuple back into host vectors — the
//! dominant wall-clock cost of the harness (dispatch overhead, not model
//! FLOPs). [`DeviceState`] inverts the ownership: params/opt live as PJRT
//! device buffers for the lifetime of a stage, the outputs of dispatch N
//! feed dispatch N+1 without ever being parsed into host tensors, and a
//! host [`ModelState`] exists only when explicitly materialized via
//! [`DeviceState::to_host`] (stage-boundary expansion, driver snapshots,
//! sweep trunk forks — see the DESIGN.md §2 host-touch table).
//!
//! [`StageExec`] is the companion dispatch handle: the four lowered
//! functions of one config (train / train_chunkK / eval / probe) resolved
//! through the compile cache **once** at stage entry, replacing the
//! per-dispatch `format!` + path join + cache probe.

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::engine::ModelState;
use super::manifest::ConfigEntry;
use super::tensor::Tensor;

/// Model + optimizer state held as PJRT device buffers, ordered exactly as
/// the manifest's layouts. Created by [`super::Engine::upload`]; updated in
/// place by the engine's `*_dev` dispatches; read back with [`to_host`].
///
/// [`to_host`]: DeviceState::to_host
pub struct DeviceState {
    pub(crate) cfg_id: String,
    pub(crate) params: Vec<xla::PjRtBuffer>,
    pub(crate) opt: Vec<xla::PjRtBuffer>,
    /// Host copy of the state, maintained ONLY under the engine's
    /// host-roundtrip reference mode so eval/probe dispatches can replicate
    /// the pre-refactor per-call param upload without an extra download.
    /// `None` on the real device-resident path.
    pub(crate) host_mirror: Option<ModelState>,
}

impl DeviceState {
    /// Config this state was uploaded for.
    pub fn cfg_id(&self) -> &str {
        &self.cfg_id
    }

    /// Guard against dispatching one config's buffers through another
    /// config's executables (the layouts would silently misalign).
    pub(crate) fn check_cfg(&self, entry: &ConfigEntry) -> Result<()> {
        if self.cfg_id != entry.cfg_id {
            bail!(
                "device state holds config '{}' but the dispatch is for '{}'",
                self.cfg_id,
                entry.cfg_id
            );
        }
        Ok(())
    }

    /// Explicit host materialization: download every buffer into a host
    /// [`ModelState`] (one copy per tensor, no revalidation pass). This is
    /// the *only* device→host path for model state; callers are the
    /// stage-boundary transition, driver snapshots/checkpoints, sweep trunk
    /// forks, and end-of-run state readers.
    pub fn to_host(&self, entry: &ConfigEntry) -> Result<ModelState> {
        self.check_cfg(entry)?;
        let params = self
            .params
            .iter()
            .zip(&entry.params)
            .map(|(buf, spec)| Tensor::from_literal(&buf.to_literal_sync()?, &spec.shape))
            .collect::<Result<Vec<_>>>()?;
        let opt = self
            .opt
            .iter()
            .zip(&entry.opt_state)
            .map(|(buf, spec)| Tensor::from_literal(&buf.to_literal_sync()?, &spec.shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelState { params, opt })
    }
}

/// Lowered functions of one config, resolved through the compile cache once
/// per binding. Callers bind only what they dispatch (the driver: train /
/// chunk / eval; one-shot tools: a single function), so unbound or absent
/// artifacts surface as errors only when actually dispatched.
pub struct StageExec {
    pub(crate) cfg_id: String,
    pub(crate) train: Option<Rc<xla::PjRtLoadedExecutable>>,
    /// The fused `train_chunk{K}` unit for this config's K.
    pub(crate) chunk: Option<Rc<xla::PjRtLoadedExecutable>>,
    pub(crate) eval: Option<Rc<xla::PjRtLoadedExecutable>>,
    pub(crate) probe: Option<Rc<xla::PjRtLoadedExecutable>>,
}

impl StageExec {
    pub fn cfg_id(&self) -> &str {
        &self.cfg_id
    }

    /// Whether a lowered per-layer probe is bound (diagnostics drivers skip
    /// layer stats for configs without one instead of erroring).
    pub fn has_probe(&self) -> bool {
        self.probe.is_some()
    }

    pub(crate) fn train(&self) -> Result<&xla::PjRtLoadedExecutable> {
        self.train
            .as_deref()
            .ok_or_else(|| anyhow!("config {} has no 'train' artifact", self.cfg_id))
    }

    pub(crate) fn chunk(&self) -> Result<&xla::PjRtLoadedExecutable> {
        self.chunk
            .as_deref()
            .ok_or_else(|| anyhow!("config {} has no fused train_chunk artifact", self.cfg_id))
    }

    pub(crate) fn eval(&self) -> Result<&xla::PjRtLoadedExecutable> {
        self.eval
            .as_deref()
            .ok_or_else(|| anyhow!("config {} has no 'eval' artifact", self.cfg_id))
    }

    pub(crate) fn probe(&self) -> Result<&xla::PjRtLoadedExecutable> {
        self.probe
            .as_deref()
            .ok_or_else(|| anyhow!("config {} has no 'probe' artifact", self.cfg_id))
    }
}
