//! PJRT execution engine: loads HLO-text artifacts, compiles them once per
//! process (keyed cache), and dispatches train/eval/probe steps over
//! device-resident state.
//!
//! Execution contract (see python/compile/aot.py and DESIGN.md §2):
//!   train:       [*params, *opt, x, y, lr]        -> tuple(params', opt', loss)
//!   train_chunkK:[*params, *opt, xs, ys, lrs]     -> tuple(params', opt', losses[K])
//!   eval:        [*params, x, y]                  -> tuple(loss)
//!   probe:       [*params, x, y]                  -> tuple(loss, grad_norms, act_rms)
//!
//! The hot path is the `*_dev` family: params/opt stay on the device as a
//! [`DeviceState`], each dispatch uploads only the batch operands, and the
//! output tuple's state elements are threaded straight back into the device
//! buffers for the next dispatch — never parsed into host `Vec<f32>`s.
//! (Multi-output executables return ONE tuple buffer on this PJRT build, so
//! the tuple literal itself is downloaded and decomposed; what the refactor
//! eliminates is every host-tensor materialization and per-dispatch state
//! upload around it, and eval/probe dispatches now move no state at all.)
//! The fused train_chunk artifact still amortizes the per-dispatch fixed
//! cost K-fold and remains the dispatch unit (EXPERIMENTS.md §Perf).
//!
//! The host-signature methods ([`Engine::train_step`] & co.) are retained as
//! the *reference path*: upload → dispatch → materialize on every call.
//! `set_host_roundtrip(true)` forces the dev path itself to round-trip state
//! through the host between units, which is how `bench-perf` measures the
//! pre-refactor baseline and how the equivalence test proves the device
//! path is a pure transport optimization (bit-identical curves).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::device_state::{DeviceState, StageExec};
use super::manifest::{ConfigEntry, InitKind};
use super::tensor::{self, IntTensor, Tensor};
use crate::util::rng::Rng;

/// Model + optimizer state on the host, ordered exactly as the manifest's
/// layouts. Since the device-resident refactor this is a *materialization*:
/// the hot path holds a [`DeviceState`] and produces a `ModelState` only at
/// the explicit host-touch points (DESIGN.md §2).
#[derive(Debug, Clone)]
pub struct ModelState {
    pub params: Vec<Tensor>,
    pub opt: Vec<Tensor>,
}

impl ModelState {
    /// Fresh state: manifest init specs for params, zeros for optimizer.
    /// Per-parameter RNG substreams make init independent of layout order.
    pub fn init(entry: &ConfigEntry, seed: u64) -> ModelState {
        let params = entry
            .params
            .iter()
            .map(|spec| match spec.init {
                InitKind::Zeros => Tensor::zeros(&spec.shape),
                InitKind::Ones => Tensor::ones(&spec.shape),
                InitKind::Normal { std } => {
                    let mut t = Tensor::zeros(&spec.shape);
                    Rng::for_param(seed, &spec.name).fill_normal(&mut t.data, std);
                    t
                }
            })
            .collect();
        let opt = entry.opt_state.iter().map(|o| Tensor::zeros(&o.shape)).collect();
        ModelState { params, opt }
    }

    pub fn param(&self, entry: &ConfigEntry, name: &str) -> Option<&Tensor> {
        entry.params.iter().position(|p| p.name == name).map(|i| &self.params[i])
    }
}

/// Cumulative wall-clock breakdown of dispatch work, split into the three
/// transport/compute phases `bench-perf` reports. `upload` covers batch
/// staging, state uploads, and output-state threading; `execute` the PJRT
/// execution itself; `download` output-tuple and materialization downloads.
#[derive(Debug, Clone, Copy, Default)]
pub struct DispatchStats {
    pub dispatches: u64,
    pub upload: Duration,
    pub execute: Duration,
    pub download: Duration,
}

pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>,
    stats: Cell<DispatchStats>,
    host_roundtrip: Cell<bool>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu()?,
            cache: RefCell::new(HashMap::new()),
            stats: Cell::new(DispatchStats::default()),
            host_roundtrip: Cell::new(false),
        })
    }

    /// Compile-or-fetch an executable for an artifact path.
    pub fn load(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Bind the lowered functions a [`crate::coordinator::RunDriver`] stage
    /// dispatches (train, the fused chunk, eval) through the compile cache —
    /// once per stage entry, instead of a name format + path join + cache
    /// probe on every dispatch. Absent artifacts stay `None` and error only
    /// if that function is dispatched. The probe is deliberately excluded:
    /// a plain (diagnostics-off) driver never dispatches it, and compiling
    /// it per stage would be pure waste — diagnostics-enabled drivers use
    /// [`Engine::bind_stage_diag`] instead.
    pub fn bind_stage(&self, entry: &ConfigEntry, root: &Path) -> Result<StageExec> {
        self.bind_fns(entry, root, &["train", "chunk", "eval"])
    }

    /// [`Engine::bind_stage`] plus the per-layer probe, for drivers running
    /// a diagnostics-enabled plan ([`crate::coordinator::RunPlan::diag`]).
    /// Configs without a lowered probe artifact still bind (`probe` stays
    /// `None`); the driver skips layer stats for them.
    pub fn bind_stage_diag(&self, entry: &ConfigEntry, root: &Path) -> Result<StageExec> {
        self.bind_fns(entry, root, &["train", "chunk", "eval", "probe"])
    }

    /// Bind only the named functions ("train" | "chunk" | "eval" | "probe"),
    /// so one-shot tools don't compile graphs they never run.
    fn bind_fns(&self, entry: &ConfigEntry, root: &Path, wanted: &[&str]) -> Result<StageExec> {
        let want = |n: &str| wanted.iter().any(|&w| w == n);
        let get = |func: &str| -> Result<Option<Rc<xla::PjRtLoadedExecutable>>> {
            if entry.artifacts.contains_key(func) {
                Ok(Some(self.load(&entry.artifact_path(root, func)?)?))
            } else {
                Ok(None)
            }
        };
        Ok(StageExec {
            cfg_id: entry.cfg_id.clone(),
            train: if want("train") { get("train")? } else { None },
            chunk: if want("chunk") { get(&format!("train_chunk{}", entry.chunk))? } else { None },
            eval: if want("eval") { get("eval")? } else { None },
            probe: if want("probe") { get("probe")? } else { None },
        })
    }

    // ------------------------------------------------------- state transport

    /// Upload a host state into device buffers — once per stage (or per
    /// sweep-fork / resume), not per dispatch.
    pub fn upload(&self, entry: &ConfigEntry, host: &ModelState) -> Result<DeviceState> {
        if host.params.len() != entry.params.len() || host.opt.len() != entry.opt_state.len() {
            bail!(
                "state layout ({} params, {} opt) does not match config '{}' ({}, {})",
                host.params.len(),
                host.opt.len(),
                entry.cfg_id,
                entry.params.len(),
                entry.opt_state.len()
            );
        }
        let params = self.upload_params(&host.params)?;
        let t0 = Instant::now();
        let opt = host.opt.iter().map(|t| self.tensor_to_device(t)).collect::<Result<Vec<_>>>()?;
        self.note(|s| s.upload += t0.elapsed());
        // Under the host-roundtrip reference mode, keep a host mirror so
        // read-only dispatches can pay the pre-refactor per-call param
        // upload without an extra (anachronistic) download first.
        let host_mirror = if self.host_roundtrip.get() { Some(host.clone()) } else { None };
        Ok(DeviceState { cfg_id: entry.cfg_id.clone(), params, opt, host_mirror })
    }

    /// Upload host parameter tensors only (eval/probe executables take no
    /// optimizer state).
    fn upload_params(&self, params: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
        let t0 = Instant::now();
        let bufs = params.iter().map(|t| self.tensor_to_device(t)).collect::<Result<Vec<_>>>()?;
        self.note(|s| s.upload += t0.elapsed());
        Ok(bufs)
    }

    /// Timed host materialization (see [`DeviceState::to_host`]).
    pub fn materialize(&self, entry: &ConfigEntry, state: &DeviceState) -> Result<ModelState> {
        let t0 = Instant::now();
        let host = state.to_host(entry)?;
        self.note(|s| s.download += t0.elapsed());
        Ok(host)
    }

    /// Snapshot-and-reset the dispatch breakdown counters.
    pub fn take_stats(&self) -> DispatchStats {
        self.stats.take()
    }

    pub fn stats(&self) -> DispatchStats {
        self.stats.get()
    }

    /// Instrumentation toggle replicating the pre-refactor transport: train
    /// dispatches materialize the device state to host tensors and re-upload
    /// them after every unit, and eval/probe dispatches re-upload every
    /// param from the host mirror on every call (the old per-eval
    /// serialization). Tensor bytes are unchanged either way — used by
    /// `bench-perf` as the baseline and by the equivalence test.
    pub fn set_host_roundtrip(&self, on: bool) {
        self.host_roundtrip.set(on);
    }

    pub fn host_roundtrip(&self) -> bool {
        self.host_roundtrip.get()
    }

    fn note(&self, f: impl FnOnce(&mut DispatchStats)) {
        let mut s = self.stats.get();
        f(&mut s);
        self.stats.set(s);
    }

    fn tensor_to_device(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.literal_to_device(&t.to_literal()?)
    }

    fn literal_to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        // Trailing optional device selects the default (sole) CPU device.
        Ok(self.client.buffer_from_host_literal(lit, None)?)
    }

    /// Execute over device buffers and download + decompose the single
    /// output tuple this PJRT build returns.
    fn dispatch(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
        n_outputs: usize,
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let out = exe.execute_b(args)?;
        self.note(|s| {
            s.dispatches += 1;
            s.execute += t0.elapsed();
        });
        if out.is_empty() || out[0].is_empty() {
            bail!("execution produced no output buffers");
        }
        let t1 = Instant::now();
        let lit = out[0][0].to_literal_sync()?;
        let elems = lit.to_tuple()?;
        self.note(|s| s.download += t1.elapsed());
        if elems.len() != n_outputs {
            bail!("artifact returned {} outputs, expected {n_outputs}", elems.len());
        }
        Ok(elems)
    }

    /// Thread the output tuple's state elements back into the device buffers
    /// (literal → buffer, no host-tensor materialization) and return the
    /// non-state tail element. Element counts are validated against the
    /// manifest layout per dispatch (cheap: one product per tensor), so a
    /// stale manifest vs regenerated artifacts fails on the first train
    /// dispatch instead of corrupting a long run.
    fn absorb_state(
        &self,
        entry: &ConfigEntry,
        state: &mut DeviceState,
        mut elems: Vec<xla::Literal>,
    ) -> Result<xla::Literal> {
        let np = entry.params.len();
        if elems.len() != np + entry.opt_state.len() + 1 {
            bail!(
                "artifact for '{}' returned {} outputs, manifest layout wants {} (stale artifacts?)",
                entry.cfg_id,
                elems.len(),
                np + entry.opt_state.len() + 1
            );
        }
        let Some(tail) = elems.pop() else {
            bail!("artifact for '{}' returned no outputs", entry.cfg_id);
        };
        let shapes = entry.params.iter().map(|p| &p.shape).chain(entry.opt_state.iter().map(|o| &o.shape));
        for (lit, shape) in elems.iter().zip(shapes) {
            let want: usize = shape.iter().product::<usize>().max(1);
            if lit.element_count() != want {
                bail!(
                    "artifact state output has {} elements, manifest shape {:?} wants {} (stale artifacts?)",
                    lit.element_count(),
                    shape,
                    want
                );
            }
        }
        if self.host_roundtrip.get() {
            // Reference mode: reproduce the pre-refactor transport exactly —
            // parse every state element into host tensors (download bucket),
            // then re-upload from the host for the next dispatch. The host
            // copy becomes the mirror (no extra clone).
            let t0 = Instant::now();
            let params = elems[..np]
                .iter()
                .zip(&entry.params)
                .map(|(lit, spec)| Tensor::from_literal(lit, &spec.shape))
                .collect::<Result<Vec<_>>>()?;
            let opt = elems[np..]
                .iter()
                .zip(&entry.opt_state)
                .map(|(lit, spec)| Tensor::from_literal(lit, &spec.shape))
                .collect::<Result<Vec<_>>>()?;
            self.note(|s| s.download += t0.elapsed());
            let host = ModelState { params, opt };
            let params_b = self.upload_params(&host.params)?;
            let t1 = Instant::now();
            let opt_b = host.opt.iter().map(|t| self.tensor_to_device(t)).collect::<Result<Vec<_>>>()?;
            self.note(|s| s.upload += t1.elapsed());
            *state = DeviceState {
                cfg_id: entry.cfg_id.clone(),
                params: params_b,
                opt: opt_b,
                host_mirror: Some(host),
            };
            return Ok(tail);
        }
        let t0 = Instant::now();
        for (i, lit) in elems.iter().enumerate() {
            let buf = self.literal_to_device(lit)?;
            if i < np {
                state.params[i] = buf;
            } else {
                state.opt[i - np] = buf;
            }
        }
        self.note(|s| s.upload += t0.elapsed());
        Ok(tail)
    }

    /// Param buffers for a read-only dispatch: the resident buffers on the
    /// real path; under host-roundtrip reference mode, a fresh per-call
    /// upload from the host mirror — the pre-refactor eval transport.
    /// `fresh` is caller-owned storage keeping the temporary buffers alive.
    fn eval_params<'s>(
        &self,
        entry: &ConfigEntry,
        state: &'s DeviceState,
        fresh: &'s mut Option<Vec<xla::PjRtBuffer>>,
    ) -> Result<&'s [xla::PjRtBuffer]> {
        if !self.host_roundtrip.get() {
            return Ok(&state.params);
        }
        let materialized;
        let host: &ModelState = match &state.host_mirror {
            Some(m) => m,
            None => {
                materialized = self.materialize(entry, state)?;
                &materialized
            }
        };
        Ok(fresh.insert(self.upload_params(&host.params)?).as_slice())
    }

    // ------------------------------------------------- device-resident path

    /// One fused K-step dispatch over device-resident state. `data` is the
    /// xs literal [K,B,S] (or images [K,B,H,W,3] for resnet), `ys` the
    /// targets, `lrs` one LR per micro-step. Returns the K per-step losses.
    pub fn train_chunk_dev(
        &self,
        exec: &StageExec,
        entry: &ConfigEntry,
        state: &mut DeviceState,
        data: &xla::Literal,
        ys: &xla::Literal,
        lrs: &[f32],
    ) -> Result<Vec<f32>> {
        state.check_cfg(entry)?;
        let exe = exec.chunk()?;
        let t0 = Instant::now();
        let data_b = self.literal_to_device(data)?;
        let ys_b = self.literal_to_device(ys)?;
        let lrs_b = self.literal_to_device(&tensor::literal_f32(&[lrs.len()], lrs)?)?;
        self.note(|s| s.upload += t0.elapsed());
        let n = entry.params.len() + entry.opt_state.len();
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(n + 3);
        args.extend(state.params.iter());
        args.extend(state.opt.iter());
        args.push(&data_b);
        args.push(&ys_b);
        args.push(&lrs_b);
        let elems = self.dispatch(exe, &args, n + 1)?;
        drop(args);
        let losses = self.absorb_state(entry, state, elems)?;
        losses.to_vec::<f32>().map_err(Into::into)
    }

    /// One single-step dispatch over device-resident state (ablations that
    /// need per-step control the chunk unit can't express).
    pub fn train_step_dev(
        &self,
        exec: &StageExec,
        entry: &ConfigEntry,
        state: &mut DeviceState,
        data: &xla::Literal,
        ys: &xla::Literal,
        lr: f32,
    ) -> Result<f32> {
        state.check_cfg(entry)?;
        let exe = exec.train()?;
        let t0 = Instant::now();
        let data_b = self.literal_to_device(data)?;
        let ys_b = self.literal_to_device(ys)?;
        let lr_b = self.literal_to_device(&tensor::literal_f32(&[], &[lr])?)?;
        self.note(|s| s.upload += t0.elapsed());
        let n = entry.params.len() + entry.opt_state.len();
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(n + 3);
        args.extend(state.params.iter());
        args.extend(state.opt.iter());
        args.push(&data_b);
        args.push(&ys_b);
        args.push(&lr_b);
        let elems = self.dispatch(exe, &args, n + 1)?;
        drop(args);
        let loss = self.absorb_state(entry, state, elems)?;
        loss.to_vec::<f32>()?
            .first()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("train step returned an empty loss"))
    }

    /// Validation loss on one batch — no state moves at all: params are
    /// already device-resident from the training dispatches.
    pub fn eval_step_dev(
        &self,
        exec: &StageExec,
        entry: &ConfigEntry,
        state: &DeviceState,
        data: &xla::Literal,
        ys: &xla::Literal,
    ) -> Result<f32> {
        state.check_cfg(entry)?;
        let exe = exec.eval()?;
        let mut fresh = None;
        let params = self.eval_params(entry, state, &mut fresh)?;
        let t0 = Instant::now();
        let data_b = self.literal_to_device(data)?;
        let ys_b = self.literal_to_device(ys)?;
        self.note(|s| s.upload += t0.elapsed());
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(params.len() + 2);
        args.extend(params.iter());
        args.push(&data_b);
        args.push(&ys_b);
        let elems = self.dispatch(exe, &args, 1)?;
        elems[0]
            .to_vec::<f32>()?
            .first()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("eval returned an empty loss"))
    }

    /// Table-1 probe over device-resident params:
    /// (loss, per-group grad norms, per-layer activation RMS).
    pub fn probe_dev(
        &self,
        exec: &StageExec,
        entry: &ConfigEntry,
        state: &DeviceState,
        x: &xla::Literal,
        y: &xla::Literal,
    ) -> Result<(f32, Vec<f32>, Vec<f32>)> {
        state.check_cfg(entry)?;
        let exe = exec.probe()?;
        let mut fresh = None;
        let params = self.eval_params(entry, state, &mut fresh)?;
        let t0 = Instant::now();
        let x_b = self.literal_to_device(x)?;
        let y_b = self.literal_to_device(y)?;
        self.note(|s| s.upload += t0.elapsed());
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(params.len() + 2);
        args.extend(params.iter());
        args.push(&x_b);
        args.push(&y_b);
        let elems = self.dispatch(exe, &args, 3)?;
        Ok((
            elems[0]
                .to_vec::<f32>()?
                .first()
                .copied()
                .ok_or_else(|| anyhow::anyhow!("probe returned an empty loss"))?,
            elems[1].to_vec::<f32>()?,
            elems[2].to_vec::<f32>()?,
        ))
    }

    // -------------------------------------------- host-signature reference

    /// Host-path reference for one fused chunk: upload, dispatch, and
    /// materialize back — every call. Kept for one-shot tools and as the
    /// host-materialize-every-unit baseline; the driver uses
    /// [`Engine::train_chunk_dev`].
    pub fn train_chunk(
        &self,
        entry: &ConfigEntry,
        root: &Path,
        state: &mut ModelState,
        xs: &IntTensor,
        ys: &IntTensor,
        lrs: &[f32],
        images: Option<&Tensor>,
    ) -> Result<Vec<f32>> {
        let exec = self.bind_fns(entry, root, &["chunk"])?;
        let mut dev = self.upload(entry, state)?;
        let data = match images {
            Some(img) => img.to_literal()?,
            None => xs.to_literal()?,
        };
        let losses = self.train_chunk_dev(&exec, entry, &mut dev, &data, &ys.to_literal()?, lrs)?;
        *state = self.materialize(entry, &dev)?;
        Ok(losses)
    }

    /// Host-path reference for one single step (see [`Engine::train_chunk`]).
    pub fn train_step(
        &self,
        entry: &ConfigEntry,
        root: &Path,
        state: &mut ModelState,
        x: &IntTensor,
        y: &IntTensor,
        lr: f32,
        images: Option<&Tensor>,
    ) -> Result<f32> {
        let exec = self.bind_fns(entry, root, &["train"])?;
        let mut dev = self.upload(entry, state)?;
        let data = match images {
            Some(img) => img.to_literal()?,
            None => x.to_literal()?,
        };
        let loss = self.train_step_dev(&exec, entry, &mut dev, &data, &y.to_literal()?, lr)?;
        *state = self.materialize(entry, &dev)?;
        Ok(loss)
    }

    /// Params-only device view for one-shot eval/probe tools (those
    /// executables take no optimizer state, so none is uploaded).
    fn upload_for_readonly(&self, entry: &ConfigEntry, state: &ModelState) -> Result<DeviceState> {
        if state.params.len() != entry.params.len() {
            bail!(
                "state has {} params, config '{}' wants {}",
                state.params.len(),
                entry.cfg_id,
                entry.params.len()
            );
        }
        Ok(DeviceState {
            cfg_id: entry.cfg_id.clone(),
            params: self.upload_params(&state.params)?,
            opt: Vec::new(),
            host_mirror: None,
        })
    }

    /// Host-path validation loss on one batch.
    pub fn eval_step(
        &self,
        entry: &ConfigEntry,
        root: &Path,
        state: &ModelState,
        x: &IntTensor,
        y: &IntTensor,
        images: Option<&Tensor>,
    ) -> Result<f32> {
        let exec = self.bind_fns(entry, root, &["eval"])?;
        let dev = self.upload_for_readonly(entry, state)?;
        let data = match images {
            Some(img) => img.to_literal()?,
            None => x.to_literal()?,
        };
        self.eval_step_dev(&exec, entry, &dev, &data, &y.to_literal()?)
    }

    /// Host-path Table-1 probe.
    pub fn probe(
        &self,
        entry: &ConfigEntry,
        root: &Path,
        state: &ModelState,
        x: &IntTensor,
        y: &IntTensor,
    ) -> Result<(f32, Vec<f32>, Vec<f32>)> {
        let exec = self.bind_fns(entry, root, &["probe"])?;
        let dev = self.upload_for_readonly(entry, state)?;
        self.probe_dev(&exec, entry, &dev, &x.to_literal()?, &y.to_literal()?)
    }
}
