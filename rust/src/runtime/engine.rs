//! PJRT execution engine: loads HLO-text artifacts, compiles them once per
//! process (keyed cache), and dispatches train/eval/probe steps.
//!
//! Execution contract (see python/compile/aot.py):
//!   train:       [*params, *opt, x, y, lr]        -> tuple(params', opt', loss)
//!   train_chunkK:[*params, *opt, xs, ys, lrs]     -> tuple(params', opt', losses[K])
//!   eval:        [*params, x, y]                  -> tuple(loss)
//!   probe:       [*params, x, y]                  -> tuple(loss, grad_norms, act_rms)
//!
//! Multi-output executables return ONE tuple buffer on this PJRT build, so
//! each dispatch downloads the tuple literal, decomposes it, and re-uploads
//! next call. The fused train_chunk artifact amortizes that round-trip K-fold
//! — it is the hot-path dispatch unit (EXPERIMENTS.md §Perf).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::manifest::{ConfigEntry, InitKind};
use super::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;

/// Model + optimizer state, ordered exactly as the manifest's layouts.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub params: Vec<Tensor>,
    pub opt: Vec<Tensor>,
}

impl ModelState {
    /// Fresh state: manifest init specs for params, zeros for optimizer.
    /// Per-parameter RNG substreams make init independent of layout order.
    pub fn init(entry: &ConfigEntry, seed: u64) -> ModelState {
        let params = entry
            .params
            .iter()
            .map(|spec| match spec.init {
                InitKind::Zeros => Tensor::zeros(&spec.shape),
                InitKind::Ones => Tensor::ones(&spec.shape),
                InitKind::Normal { std } => {
                    let mut t = Tensor::zeros(&spec.shape);
                    Rng::for_param(seed, &spec.name).fill_normal(&mut t.data, std);
                    t
                }
            })
            .collect();
        let opt = entry.opt_state.iter().map(|o| Tensor::zeros(&o.shape)).collect();
        ModelState { params, opt }
    }

    pub fn param(&self, entry: &ConfigEntry, name: &str) -> Option<&Tensor> {
        entry.params.iter().position(|p| p.name == name).map(|i| &self.params[i])
    }
}

pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()?, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile-or-fetch an executable for an artifact path.
    pub fn load(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    fn run(&self, exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// One fused K-step dispatch. `xs`/`ys` are [K,B,S] (or [K,B,...] for
    /// resnet), `lrs` has K entries. Returns the K per-micro-step losses.
    pub fn train_chunk(
        &self,
        entry: &ConfigEntry,
        root: &Path,
        state: &mut ModelState,
        xs: &IntTensor,
        ys: &IntTensor,
        lrs: &[f32],
        images: Option<&Tensor>,
    ) -> Result<Vec<f32>> {
        let func = format!("train_chunk{}", entry.chunk);
        let exe = self.load(&entry.artifact_path(root, &func)?)?;
        let mut args = Vec::with_capacity(state.params.len() + state.opt.len() + 3);
        for t in state.params.iter().chain(state.opt.iter()) {
            args.push(t.to_literal()?);
        }
        match images {
            Some(img) => args.push(img.to_literal()?),
            None => args.push(xs.to_literal()?),
        }
        args.push(ys.to_literal()?);
        args.push(Tensor::from_vec(&[lrs.len()], lrs.to_vec())?.to_literal()?);
        let outs = self.run(&exe, &args)?;
        self.unpack_state(entry, state, &outs)?;
        let losses = outs.last().unwrap().to_vec::<f32>()?;
        Ok(losses)
    }

    /// One single-step dispatch (used by ablations that need per-step control
    /// the chunk unit can't express, e.g. optimizer switching mid-chunk).
    pub fn train_step(
        &self,
        entry: &ConfigEntry,
        root: &Path,
        state: &mut ModelState,
        x: &IntTensor,
        y: &IntTensor,
        lr: f32,
        images: Option<&Tensor>,
    ) -> Result<f32> {
        let exe = self.load(&entry.artifact_path(root, "train")?)?;
        let mut args = Vec::with_capacity(state.params.len() + state.opt.len() + 3);
        for t in state.params.iter().chain(state.opt.iter()) {
            args.push(t.to_literal()?);
        }
        match images {
            Some(img) => args.push(img.to_literal()?),
            None => args.push(x.to_literal()?),
        }
        args.push(y.to_literal()?);
        args.push(Tensor::scalar(lr).to_literal()?);
        let outs = self.run(&exe, &args)?;
        self.unpack_state(entry, state, &outs)?;
        outs.last().unwrap().to_vec::<f32>().map(|v| v[0]).map_err(Into::into)
    }

    fn unpack_state(&self, entry: &ConfigEntry, state: &mut ModelState, outs: &[xla::Literal]) -> Result<()> {
        let np = state.params.len();
        let no = state.opt.len();
        if outs.len() != np + no + 1 {
            bail!("artifact returned {} outputs, expected {}", outs.len(), np + no + 1);
        }
        for (i, lit) in outs[..np].iter().enumerate() {
            state.params[i] = Tensor::from_literal(lit, &entry.params[i].shape)?;
        }
        for (i, lit) in outs[np..np + no].iter().enumerate() {
            state.opt[i] = Tensor::from_literal(lit, &entry.opt_state[i].shape)?;
        }
        Ok(())
    }

    /// Validation loss on one batch.
    pub fn eval_step(
        &self,
        entry: &ConfigEntry,
        root: &Path,
        state: &ModelState,
        x: &IntTensor,
        y: &IntTensor,
        images: Option<&Tensor>,
    ) -> Result<f32> {
        let exe = self.load(&entry.artifact_path(root, "eval")?)?;
        let mut args = Vec::with_capacity(state.params.len() + 2);
        for t in &state.params {
            args.push(t.to_literal()?);
        }
        match images {
            Some(img) => args.push(img.to_literal()?),
            None => args.push(x.to_literal()?),
        }
        args.push(y.to_literal()?);
        let outs = self.run(&exe, &args)?;
        Ok(outs[0].to_vec::<f32>()?[0])
    }

    /// Table-1 probe: (loss, per-group grad norms, per-layer activation RMS).
    pub fn probe(
        &self,
        entry: &ConfigEntry,
        root: &Path,
        state: &ModelState,
        x: &IntTensor,
        y: &IntTensor,
    ) -> Result<(f32, Vec<f32>, Vec<f32>)> {
        let exe = self.load(&entry.artifact_path(root, "probe")?)?;
        let mut args = Vec::with_capacity(state.params.len() + 2);
        for t in &state.params {
            args.push(t.to_literal()?);
        }
        args.push(x.to_literal()?);
        args.push(y.to_literal()?);
        let outs = self.run(&exe, &args)?;
        if outs.len() != 3 {
            bail!("probe returned {} outputs", outs.len());
        }
        Ok((
            outs[0].to_vec::<f32>()?[0],
            outs[1].to_vec::<f32>()?,
            outs[2].to_vec::<f32>()?,
        ))
    }
}
