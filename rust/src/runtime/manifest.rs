//! Typed view of `artifacts/manifest.json` — the L2→L3 contract.
//!
//! The manifest pins, per config id: the ordered parameter layout (names,
//! shapes, init distribution, muP fans), the optimizer-state layout, the
//! artifact filenames per lowered function, and the FLOP metadata
//! (param/active-param counts). Everything the coordinator does — init,
//! expansion remapping, step dispatch, FLOP accounting — keys off this.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
    pub muon: bool,
    pub decay: bool,
    pub fan_in: usize,
    pub fan_out: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitKind {
    Normal { std: f32 },
    Zeros,
    Ones,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Layer index for `layer.{i}.*` / `stage.{s}.block.{b}.*` names.
    pub fn layer_index(&self) -> Option<usize> {
        let mut it = self.name.split('.');
        match it.next()? {
            "layer" => it.next()?.parse().ok(),
            _ => None,
        }
    }

    /// (stage, block) for ResNet `stage.{s}.block.{b}.*` names.
    pub fn stage_block(&self) -> Option<(usize, usize)> {
        let parts: Vec<&str> = self.name.split('.').collect();
        if parts.len() >= 4 && parts[0] == "stage" && parts[2] == "block" {
            Some((parts[1].parse().ok()?, parts[3].parse().ok()?))
        } else {
            None
        }
    }

    /// Name with the layer index replaced (identity for non-layer params).
    pub fn renamed_to_layer(&self, new_idx: usize) -> String {
        if self.layer_index().is_some() {
            let rest: Vec<&str> = self.name.split('.').skip(2).collect();
            format!("layer.{new_idx}.{}", rest.join("."))
        } else {
            self.name.clone()
        }
    }
}

#[derive(Debug, Clone)]
pub struct OptStateSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct MoeInfo {
    pub n_experts: usize,
    pub top_k: usize,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub family: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub image_size: usize,
    pub n_classes: usize,
    pub stages: Option<Vec<usize>>,
    pub moe: Option<MoeInfo>,
}

#[derive(Debug, Clone)]
pub struct ConfigEntry {
    pub cfg_id: String,
    pub model: ModelInfo,
    pub opt_kind: String,
    pub params: Vec<ParamSpec>,
    pub opt_state: Vec<OptStateSpec>,
    pub param_count: usize,
    pub active_param_count: usize,
    pub chunk: usize,
    pub artifacts: BTreeMap<String, String>,
}

impl ConfigEntry {
    pub fn is_resnet(&self) -> bool {
        self.model.family == "resnet"
    }

    /// Tokens (or images) consumed per train step.
    pub fn tokens_per_step(&self) -> usize {
        if self.is_resnet() {
            self.model.batch
        } else {
            self.model.batch * self.model.seq_len
        }
    }

    pub fn artifact_path(&self, root: &Path, func: &str) -> Result<PathBuf> {
        let rel = self
            .artifacts
            .get(func)
            .ok_or_else(|| anyhow!("config {} has no artifact '{func}'", self.cfg_id))?;
        Ok(root.join(rel))
    }

    pub fn param_spec(&self, name: &str) -> Option<&ParamSpec> {
        self.params.iter().find(|p| p.name == name)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub configs: BTreeMap<String, ConfigEntry>,
}

fn parse_param(j: &Json) -> Result<ParamSpec> {
    let name = j.req("name")?.as_str().ok_or_else(|| anyhow!("param name"))?.to_string();
    let shape = j
        .req("shape")?
        .as_arr()
        .ok_or_else(|| anyhow!("param shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("shape dim")))
        .collect::<Result<Vec<_>>>()?;
    let init = match j.req("init")?.as_str() {
        Some("normal") => InitKind::Normal {
            std: j.req("std")?.as_f64().unwrap_or(0.0) as f32,
        },
        Some("zeros") => InitKind::Zeros,
        Some("ones") => InitKind::Ones,
        other => bail!("unknown init {:?}", other),
    };
    Ok(ParamSpec {
        name,
        shape,
        init,
        muon: j.get("muon").and_then(Json::as_bool).unwrap_or(false),
        decay: j.get("decay").and_then(Json::as_bool).unwrap_or(false),
        fan_in: j.get("fan_in").and_then(Json::as_usize).unwrap_or(0),
        fan_out: j.get("fan_out").and_then(Json::as_usize).unwrap_or(0),
    })
}

fn parse_model(j: &Json) -> Result<ModelInfo> {
    let stages = j.get("stages").and_then(|s| {
        s.as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect::<Vec<_>>())
    });
    let moe = j.get("moe").and_then(|m| {
        if matches!(m, Json::Null) {
            None
        } else {
            Some(MoeInfo {
                n_experts: m.get("n_experts").and_then(Json::as_usize).unwrap_or(1),
                top_k: m.get("top_k").and_then(Json::as_usize).unwrap_or(1),
            })
        }
    });
    Ok(ModelInfo {
        family: j.req("family")?.as_str().unwrap_or("").to_string(),
        n_layer: j.req("n_layer")?.as_usize().unwrap_or(0),
        d_model: j.get("d_model").and_then(Json::as_usize).unwrap_or(0),
        n_head: j.get("n_head").and_then(Json::as_usize).unwrap_or(0),
        vocab: j.get("vocab").and_then(Json::as_usize).unwrap_or(0),
        seq_len: j.get("seq_len").and_then(Json::as_usize).unwrap_or(0),
        batch: j.get("batch").and_then(Json::as_usize).unwrap_or(0),
        image_size: j.get("image_size").and_then(Json::as_usize).unwrap_or(32),
        n_classes: j.get("n_classes").and_then(Json::as_usize).unwrap_or(10),
        stages,
        moe,
    })
}

impl Manifest {
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        Self::parse(&text, root)
    }

    pub fn parse(text: &str, root: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut configs = BTreeMap::new();
        let cfgs = j.req("configs")?.as_obj().ok_or_else(|| anyhow!("configs not an object"))?;
        for (cfg_id, c) in cfgs {
            let params = c
                .req("params")?
                .as_arr()
                .ok_or_else(|| anyhow!("params"))?
                .iter()
                .map(parse_param)
                .collect::<Result<Vec<_>>>()?;
            let opt_state = c
                .req("opt_state")?
                .as_arr()
                .ok_or_else(|| anyhow!("opt_state"))?
                .iter()
                .map(|o| {
                    Ok(OptStateSpec {
                        name: o.req("name")?.as_str().unwrap_or("").to_string(),
                        shape: o
                            .req("shape")?
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let artifacts = c
                .req("artifacts")?
                .as_obj()
                .ok_or_else(|| anyhow!("artifacts"))?
                .iter()
                .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                .collect();
            configs.insert(
                cfg_id.clone(),
                ConfigEntry {
                    cfg_id: cfg_id.clone(),
                    model: parse_model(c.req("model")?)?,
                    opt_kind: c
                        .req("opt")?
                        .get("kind")
                        .and_then(Json::as_str)
                        .unwrap_or("muon_nsgd")
                        .to_string(),
                    params,
                    opt_state,
                    param_count: c.req("param_count")?.as_usize().unwrap_or(0),
                    active_param_count: c.req("active_param_count")?.as_usize().unwrap_or(0),
                    chunk: c.get("chunk").and_then(Json::as_usize).unwrap_or(1),
                    artifacts,
                },
            );
        }
        Ok(Manifest { root, configs })
    }

    pub fn get(&self, cfg_id: &str) -> Result<&ConfigEntry> {
        self.configs
            .get(cfg_id)
            .ok_or_else(|| anyhow!("unknown config '{cfg_id}' (have: {:?})",
                self.configs.keys().take(8).collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"configs":{"gpt2.l1":{
        "cfg_id":"gpt2.l1",
        "model":{"family":"gpt2","n_layer":1,"d_model":64,"n_head":4,"vocab":512,
                 "seq_len":64,"batch":8,"moe":null},
        "opt":{"kind":"muon_nsgd"},
        "params":[{"name":"embed.tok","shape":[512,64],"init":"normal","std":0.02,
                   "muon":true,"decay":false,"fan_in":512,"fan_out":64},
                  {"name":"layer.0.attn.wq","shape":[64,64],"init":"normal","std":0.125,
                   "muon":true,"decay":true,"fan_in":64,"fan_out":64}],
        "opt_state":[{"name":"mom.embed.tok","shape":[512,64]},
                     {"name":"mom.layer.0.attn.wq","shape":[64,64]}],
        "param_count":36864,"active_param_count":36864,"chunk":8,
        "artifacts":{"train":"gpt2.l1.train.hlo.txt","eval":"gpt2.l1.eval.hlo.txt"}
    }}}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let c = m.get("gpt2.l1").unwrap();
        assert_eq!(c.params.len(), 2);
        assert_eq!(c.params[1].layer_index(), Some(0));
        assert_eq!(c.params[1].renamed_to_layer(5), "layer.5.attn.wq");
        assert_eq!(c.tokens_per_step(), 512);
        assert!(matches!(c.params[0].init, InitKind::Normal { .. }));
    }

    #[test]
    fn unknown_config_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.get("nope").is_err());
    }
}
