//! L3 runtime: PJRT client wrapper, artifact manifest, device-resident state.
//!
//! `Engine` loads `artifacts/*.hlo.txt` (HLO text produced once by
//! `python/compile/aot.py`), compiles on the PJRT CPU client, and caches the
//! executables; `DeviceState` keeps params/opt as device buffers across
//! dispatches (host `ModelState` is an explicit materialization);
//! `StageExec` binds one config's lowered functions once per stage;
//! `Manifest` is the typed parameter-layout contract between the JAX build
//! path and this crate. Python never runs at request time.

pub mod device_state;
pub mod engine;
pub mod manifest;
pub mod tensor;

pub use device_state::{DeviceState, StageExec};
pub use engine::{DispatchStats, Engine, ModelState};
pub use manifest::{ConfigEntry, InitKind, Manifest, ModelInfo, OptStateSpec, ParamSpec};
pub use tensor::{literal_f32, literal_i32, IntTensor, Tensor};
