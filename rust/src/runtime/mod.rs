//! L3 runtime: PJRT client wrapper, artifact manifest, host tensors.
//!
//! `Engine` loads `artifacts/*.hlo.txt` (HLO text produced once by
//! `python/compile/aot.py`), compiles on the PJRT CPU client, and caches the
//! executables; `Manifest` is the typed parameter-layout contract between
//! the JAX build path and this crate. Python never runs at request time.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{Engine, ModelState};
pub use manifest::{ConfigEntry, InitKind, Manifest, ModelInfo, OptStateSpec, ParamSpec};
pub use tensor::{IntTensor, Tensor};
