//! Host-side tensors: the coordinator's view of parameters and batches.
//!
//! All model math happens inside the AOT'd XLA executables; host tensors
//! exist only to (a) initialize/remap parameters (expansion engine) and
//! (b) shuttle batches in and losses out. f32 everywhere for model state,
//! i32 for token batches.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product::<usize>().max(1);
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product::<usize>().max(1);
        Tensor { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Root-mean-square of entries (feature-learning scale probe).
    pub fn rms(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / self.data.len() as f64)
            .sqrt()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        // §Perf iteration 2: direct untyped-data construction — one memcpy
        // instead of vec1() + reshape() (two literal materializations).
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.shape,
            bytes,
        )?)
    }

    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
        let data = lit.to_vec::<f32>()?;
        Tensor::from_vec(shape, data)
    }
}

/// Integer batch tensor (token ids / labels).
#[derive(Debug, Clone)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<IntTensor> {
        let n: usize = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        // §Perf iteration 2 (see Tensor::to_literal); S32 payload.
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &self.shape,
            bytes,
        )?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::scalar(2.0).numel(), 1);
    }

    #[test]
    fn norms() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        assert!((t.norm() - 2.0).abs() < 1e-12);
        assert!((t.rms() - 1.0).abs() < 1e-12);
    }
}
