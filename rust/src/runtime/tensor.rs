//! Host-side tensors: the coordinator's view of parameters and batches.
//!
//! All model math happens inside the AOT'd XLA executables; host tensors
//! exist only to (a) initialize/remap parameters (expansion engine) and
//! (b) stage batches and materialize device state on demand. Since the
//! device-resident runtime (DESIGN.md §2), the training hot path never
//! constructs `Tensor`s at all — it builds batch literals straight from
//! reusable scratch slices via [`literal_f32`]/[`literal_i32`] and leaves
//! params/opt on the device ([`super::DeviceState`]). f32 everywhere for
//! model state, i32 for token batches.

use anyhow::{bail, Result};

/// Shared core of the slice→literal constructors: validate the element
/// count once, then hand the raw 4-byte payload to XLA (one memcpy).
fn literal_4byte(
    ty: xla::ElementType,
    shape: &[usize],
    ptr: *const u8,
    n_elems: usize,
) -> Result<xla::Literal> {
    let want: usize = shape.iter().product::<usize>().max(1);
    if want != n_elems {
        bail!("shape {:?} wants {} elements, got {}", shape, want, n_elems);
    }
    let bytes: &[u8] = unsafe { std::slice::from_raw_parts(ptr, n_elems * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(ty, shape, bytes)?)
}

/// Build an F32 literal directly from a slice — one memcpy, no `Tensor`
/// allocation. The dispatch hot path stages batches through this.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    literal_4byte(xla::ElementType::F32, shape, data.as_ptr() as *const u8, data.len())
}

/// Build an S32 literal directly from a slice (see [`literal_f32`]).
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    literal_4byte(xla::ElementType::S32, shape, data.as_ptr() as *const u8, data.len())
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product::<usize>().max(1);
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product::<usize>().max(1);
        Tensor { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Root-mean-square of entries (feature-learning scale probe).
    pub fn rms(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / self.data.len() as f64)
            .sqrt()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        literal_f32(&self.shape, &self.data)
    }

    /// Single-copy literal → tensor: the one `to_vec` out of the literal is
    /// the only data movement (the old path parsed into a `Vec` and then
    /// re-checked it through `from_vec`). The length check stays a hard
    /// error — it is one shape product against a stale-artifact drift that
    /// would otherwise corrupt checkpoints silently.
    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
        let data = lit.to_vec::<f32>()?;
        let n: usize = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("literal payload ({} elems) does not match shape {:?}", data.len(), shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }
}

/// Integer batch tensor (token ids / labels).
#[derive(Debug, Clone)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<IntTensor> {
        let n: usize = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        literal_i32(&self.shape, &self.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::scalar(2.0).numel(), 1);
    }

    #[test]
    fn norms() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        assert!((t.norm() - 2.0).abs() < 1e-12);
        assert!((t.rms() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slice_literal_rejects_bad_shape() {
        assert!(literal_f32(&[2, 2], &[0.0; 3]).is_err());
        assert!(literal_i32(&[3], &[1, 2]).is_err());
    }
}
