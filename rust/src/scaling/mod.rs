//! Scaling-law fits (Fig 2): power laws L = a·C^(−b) via least squares in
//! log-log space, plus comparison of exponents between progressive and
//! fixed-size families.

/// Fit log L = log a − b log C. Returns (a, b, r²).
pub fn fit_power_law(compute: &[f64], loss: &[f64]) -> (f64, f64, f64) {
    assert_eq!(compute.len(), loss.len());
    assert!(compute.len() >= 2, "need at least 2 points");
    let xs: Vec<f64> = compute.iter().map(|c| c.ln()).collect();
    let ys: Vec<f64> = loss.iter().map(|l| l.ln()).collect();
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    // r²
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(&ys)
        .map(|(x, y)| {
            let pred = intercept + slope * x;
            (y - pred) * (y - pred)
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (intercept.exp(), -slope, r2)
}

/// Compute-efficiency ratio at a target loss: how much less compute family A
/// needs than family B to reach `loss` (paper: 3–5× for progressive).
pub fn compute_ratio_at_loss(a: (f64, f64), b: (f64, f64), loss: f64) -> f64 {
    // L = k·C^(−e)  ⇒  C = (k/L)^(1/e)
    let (ka, ea) = a;
    let (kb, eb) = b;
    let ca = (ka / loss).powf(1.0 / ea);
    let cb = (kb / loss).powf(1.0 / eb);
    cb / ca
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_planted_power_law() {
        let compute: Vec<f64> = (1..=6).map(|i| 10f64.powi(i)).collect();
        let loss: Vec<f64> = compute.iter().map(|c| 7.5 * c.powf(-0.12)).collect();
        let (a, b, r2) = fit_power_law(&compute, &loss);
        assert!((a - 7.5).abs() < 1e-6);
        assert!((b - 0.12).abs() < 1e-9);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn ratio_at_loss() {
        // A reaches loss with 5x less compute than B (same exponent).
        let e = 0.1;
        let a = (5.0, e);
        let b = (5.0 * 5f64.powf(e), e);
        let r = compute_ratio_at_loss(a, b, 2.0);
        assert!((r - 5.0).abs() < 1e-9, "{r}");
    }

    #[test]
    fn better_exponent_wins_at_scale() {
        let a = (6.0, 0.15);
        let b = (6.0, 0.10);
        // At progressively lower target losses, A's advantage grows.
        let r1 = compute_ratio_at_loss(a, b, 3.0);
        let r2 = compute_ratio_at_loss(a, b, 2.0);
        assert!(r2 > r1);
    }
}
