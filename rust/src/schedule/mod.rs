//! Learning-rate schedules: WSD (warmup–stable–decay) and cosine.
//!
//! The schedule is one of the paper's two key levers (§4.2): minimizing the
//! bound-gap term Σ_{t≤τ} η_t / Σ_t η_t prefers *constant* LR before the
//! expansion and decay only at the end — exactly WSD. The coordinator
//! evaluates the schedule on the host and feeds lr as a scalar input to the
//! AOT'd train step, so a schedule change never retraces/relowers anything.

/// Schedule shape. All fractions are of the total horizon `total_steps`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Warmup to `peak`, hold, then linear decay to 0 over the last
    /// `decay_frac` of the horizon (paper: 10–20%).
    Wsd { peak: f32, warmup_frac: f32, decay_frac: f32 },
    /// Warmup to `peak`, then cosine decay to 0.
    Cosine { peak: f32, warmup_frac: f32 },
    /// Constant after warmup (ablation baseline).
    Constant { peak: f32, warmup_frac: f32 },
    /// Warmup then linear decay to 0.
    Linear { peak: f32, warmup_frac: f32 },
}

impl Schedule {
    /// Paper defaults: 2% warmup; WSD decays over the final 20% (10% for the
    /// long Fig-1 runs — callers override).
    pub fn wsd(peak: f32) -> Schedule {
        Schedule::Wsd { peak, warmup_frac: 0.02, decay_frac: 0.2 }
    }

    pub fn cosine(peak: f32) -> Schedule {
        Schedule::Cosine { peak, warmup_frac: 0.02 }
    }

    pub fn peak(&self) -> f32 {
        match *self {
            Schedule::Wsd { peak, .. }
            | Schedule::Cosine { peak, .. }
            | Schedule::Constant { peak, .. }
            | Schedule::Linear { peak, .. } => peak,
        }
    }

    /// LR at step `t` of `total` (t in [0, total)).
    pub fn lr(&self, t: usize, total: usize) -> f32 {
        debug_assert!(total > 0);
        // audit:allow(f32-narrowing): LR evaluation is f32 by contract; tau/boundary math stays f64 upstream
        let total_f = total as f32;
        let x = t as f32 / total_f;
        let warm = |wf: f32, peak: f32| -> Option<f32> {
            if wf > 0.0 && x < wf {
                // Linear ramp, starting above 0 so step 0 moves. Clamped at
                // peak: for short horizons the ramp denominator `wf · total`
                // can be < t + 1 (e.g. total=10, wf=0.02 gives 0.2), and the
                // unclamped ramp would overshoot peak several-fold —
                // violating the §4.2 schedule the bound analysis assumes.
                // audit:allow(f32-narrowing): warmup ramp position, not a tau derivation
                Some((peak * (t as f32 + 1.0) / (wf * total_f)).min(peak))
            } else {
                None
            }
        };
        match *self {
            Schedule::Wsd { peak, warmup_frac, decay_frac } => {
                if let Some(lr) = warm(warmup_frac, peak) {
                    return lr;
                }
                let decay_start = 1.0 - decay_frac;
                if x < decay_start {
                    peak
                } else {
                    // Linear to 0 at t = total.
                    peak * ((1.0 - x) / decay_frac).max(0.0)
                }
            }
            Schedule::Cosine { peak, warmup_frac } => {
                if let Some(lr) = warm(warmup_frac, peak) {
                    return lr;
                }
                let p = (x - warmup_frac) / (1.0 - warmup_frac);
                peak * 0.5 * (1.0 + (std::f32::consts::PI * p).cos())
            }
            Schedule::Constant { peak, warmup_frac } => warm(warmup_frac, peak).unwrap_or(peak),
            Schedule::Linear { peak, warmup_frac } => {
                if let Some(lr) = warm(warmup_frac, peak) {
                    return lr;
                }
                let p = (x - warmup_frac) / (1.0 - warmup_frac);
                peak * (1.0 - p)
            }
        }
    }

    /// Σ η_t over [from, to) — the quantity in the §4 bounds.
    pub fn lr_sum(&self, from: usize, to: usize, total: usize) -> f64 {
        (from..to).map(|t| self.lr(t, total) as f64).sum()
    }

    /// End of the stable phase (where expansion must happen per Takeaway 6);
    /// for non-WSD schedules this is just the horizon.
    ///
    /// Computed in f64 and rounded: the old `f32` product truncated step
    /// indices for large horizons (f32 loses integers past 2^24, so at
    /// total=10^8 the boundary was off by whole steps — and the sweep fork
    /// step derived from it disagreed with the schedule). f64 keeps integer
    /// precision to 2^53; rounding recovers the intended fraction from the
    /// f32-encoded `decay_frac` (0.2 means exactly 80% of the horizon).
    pub fn stable_end(&self, total: usize) -> usize {
        match *self {
            Schedule::Wsd { decay_frac, .. } => {
                ((1.0 - f64::from(decay_frac)) * total as f64).round() as usize
            }
            _ => total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wsd_shape() {
        let s = Schedule::Wsd { peak: 0.01, warmup_frac: 0.02, decay_frac: 0.2 };
        let total = 1000;
        // Warmup is monotone nondecreasing and ends at peak.
        assert!(s.lr(0, total) > 0.0);
        assert!(s.lr(0, total) < s.lr(10, total));
        assert!((s.lr(20, total) - 0.01).abs() < 1e-6);
        // Stable phase constant.
        assert_eq!(s.lr(100, total), s.lr(700, total));
        // Decay reaches ~0 at the end.
        assert!(s.lr(999, total) < 0.01 * 0.02);
        assert_eq!(s.stable_end(total), 800);
    }

    #[test]
    fn cosine_decays_through_midrange() {
        let s = Schedule::cosine(0.05);
        let total = 1000;
        assert!(s.lr(500, total) < 0.05 * 0.8);
        assert!(s.lr(990, total) < 0.002);
    }

    #[test]
    fn lr_sum_matches_closed_form_constant() {
        let s = Schedule::Constant { peak: 0.01, warmup_frac: 0.0 };
        let sum = s.lr_sum(0, 1000, 1000);
        assert!((sum - 10.0).abs() < 1e-6);
    }

    #[test]
    fn warmup_never_overshoots_peak() {
        // Regression: with total=10 and warmup_frac=0.02, wf·total = 0.2 < 1
        // and the unclamped ramp made step 0's LR 5× peak.
        let peak = 0.01f32;
        for total in [1usize, 2, 5, 10, 37, 50, 1000] {
            for sched in [
                Schedule::Wsd { peak, warmup_frac: 0.02, decay_frac: 0.2 },
                Schedule::cosine(peak),
                Schedule::Constant { peak, warmup_frac: 0.02 },
                Schedule::Linear { peak, warmup_frac: 0.02 },
                Schedule::Wsd { peak, warmup_frac: 0.5, decay_frac: 0.2 },
            ] {
                for t in 0..total {
                    let lr = sched.lr(t, total);
                    assert!(lr <= peak, "{sched:?}: lr({t}, {total}) = {lr} exceeds peak {peak}");
                    assert!(lr >= 0.0, "{sched:?}: lr({t}, {total}) = {lr} negative");
                }
            }
        }
        // The short-horizon case that used to overshoot, pinned explicitly.
        let s = Schedule::Wsd { peak, warmup_frac: 0.02, decay_frac: 0.2 };
        assert_eq!(s.lr(0, 10), peak);
    }

    #[test]
    fn stable_end_is_exact_for_large_horizons() {
        // Regression: the f32 product lost integer precision past 2^24.
        let wsd = |df: f32| Schedule::Wsd { peak: 0.01, warmup_frac: 0.02, decay_frac: df };
        assert_eq!(wsd(0.2).stable_end(100_000_000), 80_000_000);
        assert_eq!(wsd(0.25).stable_end(100_000_000), 75_000_000);
        assert_eq!(wsd(0.25).stable_end(100_000_001), 75_000_001);
        assert_eq!(wsd(0.1).stable_end(16_777_217), 15_099_495); // 0.9 · (2^24 + 1), rounded
        // Small horizons keep their intended fractions.
        assert_eq!(wsd(0.2).stable_end(1000), 800);
        assert_eq!(wsd(0.2).stable_end(10), 8);
        // Non-WSD schedules: stable phase runs to the horizon.
        assert_eq!(Schedule::cosine(0.01).stable_end(100_000_000), 100_000_000);
    }

    #[test]
    fn wsd_favors_late_expansion_in_bound() {
        // Paper §4.2: Σ_{t≤τ} η / Σ η smaller under WSD than cosine at the
        // same τ, because cosine front-loads its LR mass.
        let wsd = Schedule::Wsd { peak: 0.01, warmup_frac: 0.02, decay_frac: 0.1 };
        let cos = Schedule::Cosine { peak: 0.01, warmup_frac: 0.02 };
        let total = 1000;
        let tau = 800;
        let r_wsd = wsd.lr_sum(0, tau, total) / wsd.lr_sum(0, total, total);
        let r_cos = cos.lr_sum(0, tau, total) / cos.lr_sum(0, total, total);
        // After τ, WSD retains more LR mass (decay hasn't started at 0.8T
        // with 10% decay... it just started; cosine has nearly none left).
        assert!(1.0 - r_wsd > 1.0 - r_cos, "wsd {r_wsd} cos {r_cos}");
    }
}
