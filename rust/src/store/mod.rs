//! Durable sweep store: a content-addressed on-disk run cache plus a
//! crash-safe job journal (DESIGN.md §7), shareable across processes as the
//! fabric's artifact repository (DESIGN.md §9).
//!
//! The paper's figure grids train one family of models from a shared trunk;
//! before this module, a killed sweep repaid **everything**, because trunk
//! fork snapshots and finished `RunResult`s lived only in memory. The
//! [`RunStore`] persists both, keyed by content digests:
//!
//! - **runs/**`<digest>.run` — a completed run's `RunResult` (+ final model
//!   state), keyed by [`crate::coordinator::RunPlan::digest`], the full-plan
//!   hash over stages/transitions, horizon, schedule, eval cadence, and
//!   seed (name excluded: renaming a run must not repay its compute);
//! - **trunks/**`<digest>.snap` — a shared trunk's fork snapshot in the
//!   bit-exact `DPTDRV02` form ([`crate::checkpoint`]), keyed by
//!   [`crate::coordinator::RunPlan::trunk_digest`] (prefix + fork step —
//!   exactly the sweep's sharing rule);
//! - **journal.log** — append-only job journal. A cache file is trusted
//!   only once its journal line is present, and the write order is always
//!   *entry write → fsync → rename → journal append → fsync*, so a crash
//!   at any point leaves either nothing or a whole, committed entry. A torn
//!   trailing journal line is ignored at load.
//!
//! Since v2 every journal line carries the entry's **artifact manifest**
//! (byte length + content digest, [`ArtifactManifest`]), and every load
//! verifies the file against it — length first, then digest — before a
//! single field is decoded. A repository shared between hosts (the fabric's
//! coordinator serves trunk snapshots from it) can therefore never hand out
//! a silently-corrupted artifact: corruption is an error at the reader, not
//! a wrong curve three stages later. The journal also records:
//!
//! - `salt <s>` — the context salt the store was opened under
//!   ([`RunStore::open_salted`] pins it on first open; a later open under a
//!   different salt fails loudly instead of mixing contexts);
//! - `refs run:<d> trunk:<d> ...` — the set of store keys each sweep
//!   references ([`RunStore::record_refs`]), which is the liveness input to
//!   [`RunStore::gc`]: ref-counting garbage collection by journal replay
//!   (`repro store gc`), keeping shared repositories bounded.
//!
//! Results are deterministic functions of (plan, corpus, manifest), so the
//! store salts its directory with a **context fingerprint** of the corpus
//! config and manifest description ([`RunStore::context_salt`]):
//! regenerating artifacts or changing the corpus switches to a fresh
//! context directory and can never serve stale results. Bumping
//! `STORE_VERSION` (or the plan digest version) invalidates the same way —
//! by key change, never by mutation. The one thing the salt *cannot* see
//! is the training code itself: a store must not be shared across builds
//! whose numerics may differ (CI therefore keeps its bench store
//! workspace-local to one job, never in a cross-commit cache).
//!
//! Consumers: [`crate::coordinator::Sweep`] (serial path),
//! [`crate::exec::run_graph`] (pool scheduler pre-pass + completion hook),
//! and [`crate::fabric`] (coordinator-side commit point + artifact serving);
//! surfaced as `Sweep::store(dir)` / `repro ... --store-dir`.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::{self, DriverSnapshot};
use crate::coordinator::{RunPlan, RunResult};
use crate::data::Corpus;
use crate::metrics::Curve;
use crate::runtime::{ConfigEntry, Manifest, ModelState, Tensor};

const RUN_MAGIC: &[u8; 8] = b"DPTRUN02";
/// Folded into every digest preimage; bump to invalidate all entries when
/// the on-disk format or digest semantics change. v2: artifact manifests
/// (length + content digest) on every journal line, salt pinning, refs
/// lines for GC. v3: per-layer diagnostics rows in run entries (`DPTRUN02`)
/// and trunk snapshots (`DPTDRV02`).
pub const STORE_VERSION: u32 = 3;

/// 128-bit content digest of raw bytes (two independent FNV-1a-style
/// lanes), hex-encoded to 32 chars. Not cryptographic — it keys a local
/// cache and detects corruption, where the ~2^64 birthday bound is ample.
pub fn digest_bytes(bytes: &[u8]) -> String {
    let mut a: u64 = 0xcbf2_9ce4_8422_2325;
    let mut b: u64 = 0x6c62_272e_07bb_0142;
    for &byte in bytes {
        a = (a ^ u64::from(byte)).wrapping_mul(0x0000_0100_0000_01b3);
        b = (b ^ u64::from(byte).rotate_left(17) ^ 0xa5a5).wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{a:016x}{b:016x}")
}

/// [`digest_bytes`] over a string's UTF-8 bytes.
pub fn digest_str(s: &str) -> String {
    digest_bytes(s.as_bytes())
}

fn is_digest(s: &str) -> bool {
    s.len() == 32 && s.bytes().all(|c| c.is_ascii_hexdigit())
}

/// Integrity manifest of one store artifact: its exact byte length and
/// content digest, journaled at commit time and verified on **every** load
/// (length first — the cheap check — then digest) before any decoding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactManifest {
    pub len: u64,
    pub digest: String,
}

impl ArtifactManifest {
    pub fn of(bytes: &[u8]) -> ArtifactManifest {
        ArtifactManifest { len: bytes.len() as u64, digest: digest_bytes(bytes) }
    }

    /// Verify `bytes` against this manifest. Corruption is an error with a
    /// clear message, never a silent miss or a wrong hit.
    pub fn verify(&self, bytes: &[u8]) -> Result<()> {
        if bytes.len() as u64 != self.len {
            bail!(
                "artifact is {} bytes but its journal manifest says {} (truncated or corrupted store?)",
                bytes.len(),
                self.len
            );
        }
        let d = digest_bytes(bytes);
        if d != self.digest {
            bail!(
                "artifact content digest {d} does not match its journal manifest {} (corrupted store?)",
                self.digest
            );
        }
        Ok(())
    }
}

/// What [`RunStore::gc`] did (or, with `dry_run`, would do).
#[derive(Debug, Default)]
pub struct GcReport {
    pub dry_run: bool,
    /// Journaled run/trunk keys that are unreferenced by the kept refs sets.
    pub collected_runs: Vec<String>,
    pub collected_trunks: Vec<String>,
    pub live_runs: usize,
    pub live_trunks: usize,
    /// Bytes of every cache file removed (incl. stray unjournaled files).
    pub bytes_reclaimed: u64,
}

/// Content-addressed on-disk cache of sweep work. See module docs.
pub struct RunStore {
    dir: PathBuf,
    journal: File,
    /// Journaled (committed) run digests → artifact manifests. Ordered so
    /// every iteration (GC candidate lists, journal compaction) is
    /// deterministic — map order must never leak into output.
    runs: BTreeMap<String, ArtifactManifest>,
    /// Journaled trunk digests → (the trunk snapshot's ledger total, kept in
    /// the journal line bit-exactly so FLOP assembly over a fully-cached
    /// group never has to read the snapshot file; artifact manifest).
    trunks: BTreeMap<String, (f64, ArtifactManifest)>,
    /// Replayed `refs` journal lines, oldest first (tags like `run:<d>`).
    refs: Vec<Vec<String>>,
    /// Context salt the store is pinned to, if any.
    salt: Option<String>,
}

impl RunStore {
    /// Open (or create) a store rooted at `dir` and replay its journal.
    /// Unparseable or torn journal lines — the possible residue of a crash
    /// mid-append — are ignored; their cache files are simply re-earned.
    pub fn open(dir: impl AsRef<Path>) -> Result<RunStore> {
        RunStore::open_impl(dir.as_ref().to_path_buf(), None)
    }

    /// Open a store under a per-context subdirectory of `dir` (see
    /// [`RunStore::context_salt`]): entries from a different corpus or
    /// manifest can never be served. The salt is pinned in the journal on
    /// first open; re-opening the same directory under a different salt
    /// (a mis-shared repository) fails loudly.
    pub fn open_salted(dir: impl AsRef<Path>, salt: &str) -> Result<RunStore> {
        RunStore::open_impl(dir.as_ref().join(format!("ctx-{salt}")), Some(salt))
    }

    fn open_impl(dir: PathBuf, expected_salt: Option<&str>) -> Result<RunStore> {
        std::fs::create_dir_all(dir.join("runs"))
            .with_context(|| format!("creating run store {dir:?}"))?;
        std::fs::create_dir_all(dir.join("trunks"))?;
        let jpath = dir.join("journal.log");
        let mut runs = BTreeMap::new();
        let mut trunks = BTreeMap::new();
        let mut refs: Vec<Vec<String>> = Vec::new();
        let mut journal_salt: Option<String> = None;
        let mut torn_tail = false;
        if let Ok(text) = std::fs::read_to_string(&jpath) {
            torn_tail = !text.is_empty() && !text.ends_with('\n');
            for line in text.lines() {
                // The version header is the one line that must not be
                // shrugged off: trusting journal entries written under a
                // different on-disk format would surface later as spurious
                // corruption errors mid-sweep instead of a clear message.
                if let Some(v) = line.strip_prefix("DPTSTORE v") {
                    if v.trim().parse::<u32>().ok() != Some(STORE_VERSION) {
                        bail!(
                            "run store {dir:?} was written by an incompatible version \
                             (journal header '{line}'; this binary expects v{STORE_VERSION}) — \
                             delete the directory to rebuild it"
                        );
                    }
                    continue;
                }
                let mut it = line.split_whitespace();
                match it.next() {
                    Some("run") => {
                        if let (Some(d), Some(len), Some(cd)) = (it.next(), it.next(), it.next()) {
                            if is_digest(d) && is_digest(cd) && it.next().is_none() {
                                if let Ok(len) = len.parse::<u64>() {
                                    // Last line wins: a re-store after file
                                    // loss may supersede the manifest.
                                    runs.insert(
                                        d.to_string(),
                                        ArtifactManifest { len, digest: cd.to_string() },
                                    );
                                }
                            }
                        }
                    }
                    Some("trunk") => {
                        if let (Some(d), Some(fl), Some(len), Some(cd)) =
                            (it.next(), it.next(), it.next(), it.next())
                        {
                            if is_digest(d) && is_digest(cd) && it.next().is_none() {
                                if let (Ok(bits), Ok(len)) =
                                    (u64::from_str_radix(fl, 16), len.parse::<u64>())
                                {
                                    trunks.insert(
                                        d.to_string(),
                                        (
                                            f64::from_bits(bits),
                                            ArtifactManifest { len, digest: cd.to_string() },
                                        ),
                                    );
                                }
                            }
                        }
                    }
                    Some("refs") => refs.push(it.map(str::to_string).collect()),
                    Some("salt") => {
                        if let Some(s) = it.next() {
                            if it.next().is_none() {
                                journal_salt = Some(s.to_string());
                            }
                        }
                    }
                    _ => {} // header, garbage, or a torn tail line
                }
            }
        }
        if let (Some(exp), Some(found)) = (expected_salt, journal_salt.as_deref()) {
            if exp != found {
                bail!(
                    "run store {dir:?} is pinned to context salt {found}, but this sweep's \
                     context is {exp} — the store was built from a different corpus/manifest \
                     and must not be shared with this one"
                );
            }
        }
        let mut journal = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&jpath)
            .with_context(|| format!("opening store journal {jpath:?}"))?;
        if journal.metadata().map(|m| m.len()).unwrap_or(1) == 0 {
            journal.write_all(format!("DPTSTORE v{STORE_VERSION}\n").as_bytes())?;
        } else if torn_tail {
            // Terminate the crash-torn tail line before the first new
            // append — otherwise the next commit line would be concatenated
            // onto the torn fragment and silently discarded at the *next*
            // open, breaking the journal's commit guarantee exactly in the
            // crash-recovery path it exists for.
            journal.write_all(b"\n")?;
        }
        let mut store = RunStore { dir, journal, runs, trunks, refs, salt: journal_salt };
        if store.salt.is_none() {
            if let Some(exp) = expected_salt {
                store.append_journal(&format!("salt {exp}"))?;
                store.salt = Some(exp.to_string());
            }
        }
        Ok(store)
    }

    /// Fingerprint of everything *outside* the plan that determines run
    /// results: the corpus config (incl. its seed — the token streams are a
    /// deterministic function of it) and, per manifest config, the full
    /// model description (depth, width, heads, batch, seq_len, MoE, …),
    /// optimizer kind, dispatch chunk length (chunked vs single-step math
    /// differs in the last float bits), param counts, and every param spec
    /// (name, shape, init, muon/decay flags, fan-in/out) and opt-state
    /// layout. Artifact *paths* are deliberately excluded — the same
    /// artifacts mounted elsewhere must still hit. Digested over the
    /// manifest's BTreeMap order, so it is stable across processes.
    pub fn context_salt(manifest: &Manifest, corpus: &Corpus) -> String {
        let mut desc = format!("ctxv{STORE_VERSION}|corpus={:?}", corpus.cfg);
        for (id, c) in &manifest.configs {
            let _ = write!(
                desc,
                "|cfg {id} model={:?} opt={} chunk={} n={}/{} params=",
                c.model, c.opt_kind, c.chunk, c.param_count, c.active_param_count
            );
            for p in &c.params {
                let _ = write!(desc, "{p:?},");
            }
            desc.push_str(" os=");
            for o in &c.opt_state {
                let _ = write!(desc, "{}:{:?},", o.name, o.shape);
            }
        }
        digest_str(&desc)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The context salt this store is pinned to, if it was opened salted
    /// (the fabric handshake compares this across processes).
    pub fn salt(&self) -> Option<&str> {
        self.salt.as_deref()
    }

    fn run_path(&self, digest: &str) -> PathBuf {
        self.dir.join("runs").join(format!("{digest}.run"))
    }

    fn trunk_path(&self, digest: &str) -> PathBuf {
        self.dir.join("trunks").join(format!("{digest}.snap"))
    }

    /// One write + fsync per line; the journal append is the commit point
    /// of every store entry (files without a journal line are ignored).
    fn append_journal(&mut self, line: &str) -> Result<()> {
        self.journal
            .write_all(format!("{line}\n").as_bytes())
            .context("appending to store journal")?;
        self.journal.sync_data().context("syncing store journal")?;
        Ok(())
    }

    // ------------------------------------------------------------ run cache

    /// True when `digest` is journaled *and* its entry file is present.
    pub fn has_run(&self, digest: &str) -> bool {
        self.runs.contains_key(digest) && self.run_path(digest).exists()
    }

    /// Cache lookup for one plan. On a hit, the stored curve is renamed to
    /// the requesting plan (digests are name-blind). Returns `None` on a
    /// miss, or when `keep_state` asks for a final model state the entry
    /// does not carry; a journaled-but-corrupted entry is an **error**,
    /// never a silent miss or hit.
    pub fn lookup(
        &self,
        plan: &RunPlan,
        keep_state: bool,
    ) -> Result<Option<(RunResult, Option<ModelState>)>> {
        let digest = plan.digest();
        if !self.has_run(&digest) {
            return Ok(None);
        }
        let (result, state) = self.load_run(&digest, plan.name(), keep_state)?;
        if keep_state && state.is_none() {
            return Ok(None);
        }
        Ok(Some((result, state)))
    }

    /// Persist a completed run: atomic file write (+fsync), then journal
    /// commit with the entry's artifact manifest. Idempotent — re-storing a
    /// committed digest is a no-op (or a file rewrite when the entry file
    /// was deleted out from under us, e.g. by [`RunStore::gc`]).
    pub fn store_run(
        &mut self,
        digest: &str,
        result: &RunResult,
        state: Option<&ModelState>,
    ) -> Result<()> {
        let path = self.run_path(digest);
        if self.runs.contains_key(digest) && path.exists() {
            return Ok(());
        }
        let mut bytes = Vec::new();
        write_run_entry(&mut bytes, result, state)?;
        let manifest = ArtifactManifest::of(&bytes);
        checkpoint::write_atomic(&path, |f| f.write_all(&bytes).map_err(Into::into))
            .with_context(|| format!("writing run-cache entry {digest}"))?;
        if self.runs.get(digest) != Some(&manifest) {
            self.append_journal(&format!("run {digest} {} {}", manifest.len, manifest.digest))?;
            self.runs.insert(digest.to_string(), manifest);
        }
        Ok(())
    }

    /// Read a committed run entry, renaming its curve to `run_name`. The
    /// file's bytes are verified against the journaled artifact manifest
    /// (length, then content digest) before any field is decoded. With
    /// `want_state` false the final-state section is read for verification
    /// but never decoded into tensors.
    pub fn load_run(
        &self,
        digest: &str,
        run_name: &str,
        want_state: bool,
    ) -> Result<(RunResult, Option<ModelState>)> {
        let path = self.run_path(digest);
        let read = || -> Result<(RunResult, Option<ModelState>)> {
            let manifest = self
                .runs
                .get(digest)
                .ok_or_else(|| anyhow!("run {digest} has no journal entry"))?;
            let bytes = std::fs::read(&path)?;
            manifest.verify(&bytes)?;
            read_run_entry(&mut &bytes[..], run_name, want_state)
        };
        read().with_context(|| {
            format!("reading cached run {digest} from {path:?} (truncated or corrupted store?)")
        })
    }

    // ---------------------------------------------------------- trunk cache

    /// Journaled trunk-prefix cost, if the trunk ever completed. Survives
    /// snapshot-file deletion — enough for bit-exact FLOP assembly over a
    /// fully-cached group.
    pub fn trunk_flops(&self, digest: &str) -> Option<f64> {
        self.trunks.get(digest).map(|(f, _)| *f)
    }

    /// True when the trunk is journaled and its snapshot file is present
    /// (i.e. variants can actually fork from it).
    pub fn has_trunk_snapshot(&self, digest: &str) -> bool {
        self.trunks.contains_key(digest) && self.trunk_path(digest).exists()
    }

    /// Journaled artifact manifest (length + content digest) of a committed
    /// trunk snapshot. The fabric uses this to verify worker-advertised
    /// cache entries without touching the snapshot file.
    pub fn trunk_manifest(&self, digest: &str) -> Option<ArtifactManifest> {
        self.trunks.get(digest).map(|(_, m)| m.clone())
    }

    /// Persist a trunk fork snapshot (`DPTDRV02` via [`crate::checkpoint`]),
    /// then journal `trunk <digest> <ledger-total-bits> <len> <content>`.
    pub fn store_trunk(
        &mut self,
        digest: &str,
        snap: &DriverSnapshot,
        entry: &ConfigEntry,
    ) -> Result<()> {
        let path = self.trunk_path(digest);
        if self.trunks.contains_key(digest) && path.exists() {
            return Ok(());
        }
        let mut bytes = Vec::new();
        checkpoint::write_snapshot_to(&mut bytes, snap, entry)
            .with_context(|| format!("serializing trunk-cache entry {digest}"))?;
        let manifest = ArtifactManifest::of(&bytes);
        checkpoint::write_atomic(&path, |f| f.write_all(&bytes).map_err(Into::into))
            .with_context(|| format!("writing trunk-cache entry {digest}"))?;
        if self.trunks.get(digest).map(|(_, m)| m) != Some(&manifest) {
            self.append_journal(&format!(
                "trunk {digest} {:016x} {} {}",
                snap.ledger.total.to_bits(),
                manifest.len,
                manifest.digest
            ))?;
            self.trunks.insert(digest.to_string(), (snap.ledger.total, manifest));
        }
        Ok(())
    }

    /// Read a committed trunk snapshot's raw verified bytes (the fabric
    /// serves these to workers without decoding them).
    pub fn load_trunk_bytes(&self, digest: &str) -> Result<Vec<u8>> {
        let path = self.trunk_path(digest);
        let read = || -> Result<Vec<u8>> {
            let (_, manifest) = self
                .trunks
                .get(digest)
                .ok_or_else(|| anyhow!("trunk {digest} has no journal entry"))?;
            let bytes = std::fs::read(&path)?;
            manifest.verify(&bytes)?;
            Ok(bytes)
        };
        read().with_context(|| format!("reading cached trunk {digest} from store {:?}", self.dir))
    }

    /// Load a committed trunk snapshot, validated against the journaled
    /// artifact manifest and then against `entry` (the group's stage-0
    /// config). Corruption is an error, never a cache hit.
    pub fn load_trunk(&self, digest: &str, entry: &ConfigEntry) -> Result<DriverSnapshot> {
        let bytes = self.load_trunk_bytes(digest)?;
        checkpoint::read_snapshot_from(&mut &bytes[..], entry)
            .with_context(|| format!("reading cached trunk {digest} from store {:?}", self.dir))
    }

    /// [`RunStore::load_trunk`] plus the fork-step invariant both sweep
    /// paths must enforce identically: the cached snapshot has to sit
    /// exactly at the group's fork boundary.
    pub fn load_trunk_at(
        &self,
        digest: &str,
        entry: &ConfigEntry,
        fork_step: usize,
        plan_name: &str,
    ) -> Result<DriverSnapshot> {
        let snap = self.load_trunk(digest, entry)?;
        if snap.step != fork_step {
            bail!(
                "cached trunk {digest} for '{plan_name}' is at step {} instead of the fork boundary {fork_step}",
                snap.step
            );
        }
        Ok(snap)
    }

    // ----------------------------------------------------- refs + GC

    /// Journal the set of store keys a sweep references (its plan digests
    /// and trunk digests) — the liveness input to [`RunStore::gc`]. Called
    /// once per sweep before execution, so even an interrupted sweep's
    /// partial artifacts stay referenced.
    pub fn record_refs<'a>(
        &mut self,
        run_digests: impl IntoIterator<Item = &'a str>,
        trunk_digests: impl IntoIterator<Item = &'a str>,
    ) -> Result<()> {
        let mut tags: Vec<String> =
            run_digests.into_iter().map(|d| format!("run:{d}")).collect();
        tags.extend(trunk_digests.into_iter().map(|d| format!("trunk:{d}")));
        tags.sort();
        tags.dedup();
        if self.refs.last() == Some(&tags) {
            // Re-running the same sweep (e.g. `serve --resume` restarts)
            // appends nothing: the journal stays bounded and the GC
            // keep-window still counts distinct sweeps.
            return Ok(());
        }
        self.append_journal(&format!("refs {}", tags.join(" ")))?;
        self.refs.push(tags);
        Ok(())
    }

    /// True when some journaled `refs` set covers every one of this sweep's
    /// keys — i.e. the journal has seen this sweep before. `serve --resume`
    /// uses this to refuse resuming a sweep the store knows nothing about
    /// (a typo'd store dir would otherwise silently run from scratch).
    pub fn refs_recorded<'a>(
        &self,
        run_digests: impl IntoIterator<Item = &'a str>,
        trunk_digests: impl IntoIterator<Item = &'a str>,
    ) -> bool {
        let mut tags: Vec<String> =
            run_digests.into_iter().map(|d| format!("run:{d}")).collect();
        tags.extend(trunk_digests.into_iter().map(|d| format!("trunk:{d}")));
        tags.sort();
        tags.dedup();
        self.refs.iter().any(|set| tags.iter().all(|t| set.contains(t)))
    }

    /// Ref-counting garbage collection by journal replay: every journaled
    /// entry not referenced by the last `keep` (≥1) `refs` sets is
    /// collected, along with any stray unjournaled file in the cache
    /// directories (torn temp files are invisible to lookups but still
    /// occupy bytes). A store with **no** refs lines collects nothing —
    /// liveness would be a guess. With `dry_run` the report is computed and
    /// nothing is touched. A real GC ends by compacting the journal
    /// atomically (tmp + fsync + rename), so collected keys do not
    /// resurrect on reopen.
    pub fn gc(&mut self, dry_run: bool, keep: usize) -> Result<GcReport> {
        let mut report = GcReport { dry_run, ..Default::default() };
        if self.refs.is_empty() {
            report.live_runs = self.runs.len();
            report.live_trunks = self.trunks.len();
            return Ok(report);
        }
        let keep = keep.max(1);
        let start = self.refs.len().saturating_sub(keep);
        let mut live_runs: BTreeSet<&str> = BTreeSet::new();
        let mut live_trunks: BTreeSet<&str> = BTreeSet::new();
        for tags in &self.refs[start..] {
            for t in tags {
                if let Some(d) = t.strip_prefix("run:") {
                    live_runs.insert(d);
                } else if let Some(d) = t.strip_prefix("trunk:") {
                    live_trunks.insert(d);
                }
            }
        }
        // `runs`/`trunks` are BTreeMaps, so the candidate lists come out in
        // sorted (deterministic) order without a post-hoc sort.
        report.collected_runs =
            self.runs.keys().filter(|d| !live_runs.contains(d.as_str())).cloned().collect();
        report.collected_trunks =
            self.trunks.keys().filter(|d| !live_trunks.contains(d.as_str())).cloned().collect();
        report.live_runs = self.runs.len() - report.collected_runs.len();
        report.live_trunks = self.trunks.len() - report.collected_trunks.len();
        // Keep exactly the journaled-and-live files; everything else in the
        // cache directories (dead entries, unjournaled strays, leftover
        // temp files) is collectable.
        let keep_files: [BTreeSet<String>; 2] = [
            self.runs
                .keys()
                .filter(|d| live_runs.contains(d.as_str()))
                .map(|d| format!("{d}.run"))
                .collect(),
            self.trunks
                .keys()
                .filter(|d| live_trunks.contains(d.as_str()))
                .map(|d| format!("{d}.snap"))
                .collect(),
        ];
        for (sub, keep_files) in ["runs", "trunks"].iter().zip(&keep_files) {
            let dirp = self.dir.join(sub);
            for e in std::fs::read_dir(&dirp).with_context(|| format!("listing {dirp:?}"))? {
                let e = e?;
                let name = e.file_name().to_string_lossy().into_owned();
                if keep_files.contains(&name) {
                    continue;
                }
                report.bytes_reclaimed += e.metadata().map(|m| m.len()).unwrap_or(0);
                if !dry_run {
                    let path = e.path();
                    std::fs::remove_file(&path)
                        .with_context(|| format!("collecting {path:?}"))?;
                }
            }
        }
        if !dry_run {
            for d in &report.collected_runs {
                self.runs.remove(d);
            }
            for d in &report.collected_trunks {
                self.trunks.remove(d);
            }
            if start > 0 {
                self.refs.drain(..start);
            }
            self.compact_journal()?;
        }
        Ok(report)
    }

    /// Rewrite the journal to exactly the in-memory state (header, salt,
    /// surviving entries, kept refs), atomically: tmp + fsync + rename,
    /// then reopen the append handle on the new file.
    fn compact_journal(&mut self) -> Result<()> {
        let jpath = self.dir.join("journal.log");
        let tmp = self.dir.join(format!("journal.tmp{}", std::process::id()));
        let mut text = format!("DPTSTORE v{STORE_VERSION}\n");
        if let Some(s) = &self.salt {
            let _ = writeln!(text, "salt {s}");
        }
        // BTreeMap iteration is already digest-sorted — the compacted
        // journal is a canonical, deterministic rendering of store state.
        for (d, m) in &self.runs {
            let _ = writeln!(text, "run {d} {} {}", m.len, m.digest);
        }
        for (d, (fl, m)) in &self.trunks {
            let _ = writeln!(text, "trunk {d} {:016x} {} {}", fl.to_bits(), m.len, m.digest);
        }
        for tags in &self.refs {
            let _ = writeln!(text, "refs {}", tags.join(" "));
        }
        {
            let f = File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
            let mut w = std::io::BufWriter::new(f);
            w.write_all(text.as_bytes())?;
            w.flush()?;
            w.get_ref().sync_all()?;
        }
        std::fs::rename(&tmp, &jpath).context("publishing compacted store journal")?;
        self.journal = OpenOptions::new()
            .append(true)
            .open(&jpath)
            .context("reopening compacted store journal")?;
        Ok(())
    }
}

impl std::fmt::Debug for RunStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RunStore")
            .field("dir", &self.dir)
            .field("runs", &self.runs.len())
            .field("trunks", &self.trunks.len())
            .field("refs", &self.refs.len())
            .field("salt", &self.salt)
            .finish()
    }
}

// --------------------------------------------------- run-entry byte codec
// (shared by the on-disk store and the fabric wire: a `RunResult` shipped
// over TCP is byte-identical to its cache-entry form)

/// Serialize a completed run (`DPTRUN02`): curve, ledger, boundaries,
/// per-layer diagnostics rows (empty unless the plan enabled them), final
/// val loss, and optionally the final model state.
pub fn write_run_entry(
    f: &mut impl Write,
    result: &RunResult,
    state: Option<&ModelState>,
) -> Result<()> {
    f.write_all(RUN_MAGIC)?;
    checkpoint::write_str(f, &result.curve.name)?;
    checkpoint::write_f32(f, result.final_val_loss)?;
    checkpoint::write_ledger(f, &result.ledger)?;
    checkpoint::write_curve_points(f, &result.curve.points)?;
    checkpoint::write_boundaries(f, &result.boundaries)?;
    checkpoint::write_layer_stats(f, &result.layer_stats)?;
    match state {
        None => checkpoint::write_u64(f, 0)?,
        Some(s) => {
            checkpoint::write_u64(f, 1)?;
            write_tensor_list(f, &s.params)?;
            write_tensor_list(f, &s.opt)?;
        }
    }
    Ok(())
}

/// Decode a `DPTRUN02` run entry, renaming its curve to `run_name`. With
/// `want_state` false the final-state section — the dominant bytes of an
/// entry — is never decoded or allocated.
pub fn read_run_entry(
    f: &mut impl Read,
    run_name: &str,
    want_state: bool,
) -> Result<(RunResult, Option<ModelState>)> {
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != RUN_MAGIC {
        bail!("not a DPT run-cache entry");
    }
    let _stored_name = checkpoint::read_str(f)?;
    let final_val_loss = checkpoint::read_f32(f)?;
    let ledger = checkpoint::read_ledger(f)?;
    let mut curve = Curve::new(run_name);
    curve.points = checkpoint::read_curve_points(f)?;
    let boundaries = checkpoint::read_boundaries(f)?;
    let layer_stats = checkpoint::read_layer_stats(f)?;
    let state = if !want_state {
        None
    } else {
        match checkpoint::read_u64(f)? {
            0 => None,
            1 => Some(ModelState {
                params: read_tensor_list(f)?,
                opt: read_tensor_list(f)?,
            }),
            other => bail!("bad state-presence flag {other}"),
        }
    };
    Ok((RunResult { curve, ledger, boundaries, final_val_loss, layer_stats }, state))
}

/// Positional (nameless) tensor list — the final-state section of a run
/// entry. Shapes are self-describing; layout order is the manifest order
/// the run finished in.
fn write_tensor_list(f: &mut impl Write, tensors: &[Tensor]) -> Result<()> {
    checkpoint::write_u64(f, tensors.len() as u64)?;
    for t in tensors {
        checkpoint::write_tensor(f, "", t)?;
    }
    Ok(())
}

fn read_tensor_list(f: &mut impl Read) -> Result<Vec<Tensor>> {
    let n = checkpoint::read_count(f)?;
    if n > 1 << 16 {
        bail!("implausible tensor count {n}");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let (_, t) = checkpoint::read_tensor(f)?;
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunBuilder;
    use crate::expansion::ExpandSpec;
    use crate::flops::FlopLedger;
    use crate::metrics::CurvePoint;
    use crate::schedule::Schedule;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dpt_store_{name}_{}", std::process::id()))
    }

    fn sched() -> Schedule {
        Schedule::Constant { peak: 0.01, warmup_frac: 0.02 }
    }

    fn plan(name: &str, tau: usize, seed: u64) -> RunPlan {
        RunBuilder::progressive(name, "s", "l", tau, 100, sched(), ExpandSpec::default())
            .seed(seed)
            .build()
            .unwrap()
    }

    fn result(name: &str) -> RunResult {
        let mut curve = Curve::new(name);
        curve.push(CurvePoint { step: 10, tokens: 640, flops: 1e6, train_loss: 2.5, val_loss: 2.6, lr: 0.01 });
        curve.push(CurvePoint { step: 20, tokens: 1280, flops: 2e6, train_loss: 2.1, val_loss: 2.2, lr: 0.01 });
        RunResult {
            curve,
            ledger: FlopLedger { total: 2e6, tokens: 1280, stages: vec![("s".into(), 20, 2e6)] },
            boundaries: vec![(10, "l".into())],
            final_val_loss: 2.2,
            layer_stats: vec![crate::diag::LayerStatsRow {
                step: 20,
                tokens: 1280,
                layer: 0,
                rung: "l".into(),
                grad_norm: 0.5,
                act_rms: 1.0,
                uw_ratio: 0.005,
            }],
        }
    }

    fn state() -> ModelState {
        ModelState {
            params: vec![Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap()],
            opt: vec![Tensor::from_vec(&[2], vec![-0.5, 0.25]).unwrap()],
        }
    }

    #[test]
    fn digest_is_stable_and_content_sensitive() {
        let a = plan("a", 40, 1);
        assert_eq!(a.digest(), plan("renamed", 40, 1).digest(), "name must not affect the digest");
        assert_eq!(a.digest(), a.digest());
        assert!(is_digest(&a.digest()));
        assert_ne!(a.digest(), plan("a", 40, 2).digest(), "seed must affect the digest");
        assert_ne!(a.digest(), plan("a", 60, 1).digest(), "boundary must affect the digest");
        // The expansion spec only matters after the fork: same trunk digest,
        // different full digest.
        let b = RunBuilder::progressive("b", "s", "l", 40, 100, sched(), ExpandSpec { seed: 99, ..Default::default() })
            .seed(1)
            .build()
            .unwrap();
        assert_ne!(a.digest(), b.digest());
        assert_eq!(a.trunk_digest(), b.trunk_digest());
        assert_ne!(a.trunk_digest(), plan("a", 60, 1).trunk_digest());
        // Byte and string digests agree on the same content.
        assert_eq!(digest_str("abc"), digest_bytes(b"abc"));
    }

    #[test]
    fn run_roundtrip_is_bit_exact_and_renames() {
        let dir = tmp("run_rt");
        std::fs::remove_dir_all(&dir).ok();
        let mut store = RunStore::open(&dir).unwrap();
        let p = plan("mine", 40, 1);
        let digest = p.digest();
        let res = result("original");
        let st = state();
        assert!(!store.has_run(&digest));
        store.store_run(&digest, &res, Some(&st)).unwrap();
        assert!(store.has_run(&digest));
        let (loaded, lstate) = store.load_run(&digest, "mine", true).unwrap();
        assert_eq!(loaded.curve.name, "mine", "loaded curve must take the requesting plan's name");
        assert_eq!(loaded.curve.points, res.curve.points);
        assert_eq!(loaded.boundaries, res.boundaries);
        assert_eq!(loaded.layer_stats, res.layer_stats);
        assert_eq!(loaded.ledger.total.to_bits(), res.ledger.total.to_bits());
        assert_eq!(loaded.ledger.tokens, res.ledger.tokens);
        assert_eq!(loaded.ledger.stages, res.ledger.stages);
        assert_eq!(loaded.final_val_loss.to_bits(), res.final_val_loss.to_bits());
        let lstate = lstate.expect("state stored");
        assert_eq!(lstate.params[0].data, st.params[0].data);
        assert_eq!(lstate.opt[0].data, st.opt[0].data);
        // lookup honors keep_state both ways.
        let hit = store.lookup(&p, false).unwrap().expect("hit");
        assert!(hit.1.is_none());
        let hit = store.lookup(&p, true).unwrap().expect("hit");
        assert!(hit.1.is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn journal_is_the_commit_point() {
        let dir = tmp("commit");
        std::fs::remove_dir_all(&dir).ok();
        let store = RunStore::open(&dir).unwrap();
        let p = plan("p", 40, 1);
        let digest = p.digest();
        // A cache file that was never journaled (torn write before the
        // journal append) must be invisible...
        std::fs::write(dir.join("runs").join(format!("{digest}.run")), b"garbage").unwrap();
        assert!(!store.has_run(&digest));
        assert!(store.lookup(&p, false).unwrap().is_none());
        drop(store);
        // ...and a journaled digest whose file disappeared is a plain miss.
        let mut store = RunStore::open(&dir).unwrap();
        store.store_run(&digest, &result("p"), None).unwrap();
        std::fs::remove_file(store.run_path(&digest)).unwrap();
        assert!(!store.has_run(&digest));
        // Re-storing after deletion rewrites the file under the old journal
        // entry (idempotent commit).
        store.store_run(&digest, &result("p"), None).unwrap();
        assert!(store.has_run(&digest));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_committed_entry_is_an_error_not_a_hit() {
        let dir = tmp("corrupt");
        std::fs::remove_dir_all(&dir).ok();
        let mut store = RunStore::open(&dir).unwrap();
        let p = plan("p", 40, 1);
        let digest = p.digest();
        store.store_run(&digest, &result("p"), Some(&state())).unwrap();
        let path = store.run_path(&digest);
        let bytes = std::fs::read(&path).unwrap();
        // Truncation is caught by the manifest length check...
        std::fs::write(&path, &bytes[..60]).unwrap();
        assert!(store.lookup(&p, false).is_err(), "truncated committed entry must error");
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(store.lookup(&p, true).is_err(), "state-truncated entry must error");
        // ...and a same-length bit flip by the content digest, even in the
        // state section a state-less lookup never decodes.
        let mut flipped = bytes.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        let err = store.lookup(&p, false).unwrap_err();
        assert!(format!("{err:#}").contains("content digest"), "{err:#}");
        std::fs::write(&path, b"XXXXXXXXtrash").unwrap();
        assert!(store.lookup(&p, false).is_err(), "wrong-magic committed entry must error");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn journal_survives_reopen_and_ignores_torn_tail() {
        let dir = tmp("reopen");
        std::fs::remove_dir_all(&dir).ok();
        let p = plan("p", 40, 1);
        let digest = p.digest();
        {
            let mut store = RunStore::open(&dir).unwrap();
            store.store_run(&digest, &result("p"), None).unwrap();
        }
        // Simulate a crash mid-append: a torn trailing line.
        {
            let mut j = OpenOptions::new().append(true).open(dir.join("journal.log")).unwrap();
            j.write_all(b"run deadbeef").unwrap(); // no newline, short digest
        }
        let mut store = RunStore::open(&dir).unwrap();
        assert!(store.has_run(&digest), "journal must survive reopen");
        assert!(!store.has_run("deadbeef"), "torn tail line must be ignored");
        // Commits made *after* recovering from a torn tail must not be
        // concatenated onto the fragment — they must survive a reopen.
        let p2 = plan("p2", 60, 1);
        store.store_run(&p2.digest(), &result("p2"), None).unwrap();
        drop(store);
        let store = RunStore::open(&dir).unwrap();
        assert!(store.has_run(&digest));
        assert!(
            store.has_run(&p2.digest()),
            "commit after a torn tail must be journaled on its own line"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trunk_flops_survive_without_snapshot_file() {
        let dir = tmp("trunkflops");
        std::fs::remove_dir_all(&dir).ok();
        let mut store = RunStore::open(&dir).unwrap();
        let digest = digest_str("some trunk");
        // Hand-journal a trunk (as if its snapshot was pruned later).
        let m = ArtifactManifest::of(b"");
        store
            .append_journal(&format!(
                "trunk {digest} {:016x} {} {}",
                1234.5f64.to_bits(),
                m.len,
                m.digest
            ))
            .unwrap();
        store.trunks.insert(digest.clone(), (1234.5, m));
        drop(store);
        let store = RunStore::open(&dir).unwrap();
        assert_eq!(store.trunk_flops(&digest).map(f64::to_bits), Some(1234.5f64.to_bits()));
        assert!(!store.has_trunk_snapshot(&digest));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lookup_misses_when_state_required_but_absent() {
        let dir = tmp("nostate");
        std::fs::remove_dir_all(&dir).ok();
        let mut store = RunStore::open(&dir).unwrap();
        let p = plan("p", 40, 1);
        store.store_run(&p.digest(), &result("p"), None).unwrap();
        assert!(store.lookup(&p, false).unwrap().is_some());
        assert!(store.lookup(&p, true).unwrap().is_none(), "state-less entry cannot serve keep_states");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn salted_store_pins_its_context() {
        let dir = tmp("saltpin");
        std::fs::remove_dir_all(&dir).ok();
        let s1 = digest_str("context one");
        let s2 = digest_str("context two");
        {
            let store = RunStore::open_salted(&dir, &s1).unwrap();
            assert_eq!(store.salt(), Some(s1.as_str()));
        }
        // Reopening under the same salt is fine (and the pin survives).
        {
            let store = RunStore::open_salted(&dir, &s1).unwrap();
            assert_eq!(store.salt(), Some(s1.as_str()));
        }
        // Simulate mis-sharing: the ctx directory of context one is handed
        // to a sweep in context two. The pinned salt must refuse.
        std::fs::rename(dir.join(format!("ctx-{s1}")), dir.join(format!("ctx-{s2}"))).unwrap();
        let err = RunStore::open_salted(&dir, &s2).unwrap_err().to_string();
        assert!(err.contains("pinned to context salt"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_collects_only_unreferenced_entries() {
        let dir = tmp("gc");
        std::fs::remove_dir_all(&dir).ok();
        let mut store = RunStore::open(&dir).unwrap();
        let keep_p = plan("keep", 40, 1);
        let drop_a = plan("drop_a", 40, 2);
        let drop_b = plan("drop_b", 60, 3);
        for p in [&keep_p, &drop_a, &drop_b] {
            store.store_run(&p.digest(), &result(p.name()), None).unwrap();
        }
        // Without any refs line, GC must collect nothing.
        let report = store.gc(false, 1).unwrap();
        assert!(report.collected_runs.is_empty());
        assert_eq!(report.live_runs, 3);
        // Record a sweep referencing only `keep`.
        store.record_refs([keep_p.digest().as_str()], []).unwrap();
        // Stray unjournaled file is collectable too.
        std::fs::write(dir.join("runs").join("stray.run.tmp999"), b"leftover").unwrap();
        let dry = store.gc(true, 1).unwrap();
        assert!(dry.dry_run);
        let mut expected = vec![drop_a.digest(), drop_b.digest()];
        expected.sort();
        assert_eq!(dry.collected_runs, expected);
        assert!(dry.bytes_reclaimed > 0);
        assert!(store.has_run(&drop_a.digest()), "dry run must not delete");
        let real = store.gc(false, 1).unwrap();
        assert_eq!(real.collected_runs, expected);
        assert!(store.has_run(&keep_p.digest()));
        assert!(!store.has_run(&drop_a.digest()));
        assert!(!store.has_run(&drop_b.digest()));
        assert!(!dir.join("runs").join("stray.run.tmp999").exists());
        drop(store);
        // The compacted journal must not resurrect collected keys, and the
        // survivor must still verify.
        let mut store = RunStore::open(&dir).unwrap();
        assert!(store.has_run(&keep_p.digest()));
        assert!(!store.has_run(&drop_a.digest()));
        assert!(store.lookup(&keep_p, false).unwrap().is_some());
        // A collected entry can be re-earned.
        store.store_run(&drop_a.digest(), &result("drop_a"), None).unwrap();
        assert!(store.has_run(&drop_a.digest()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_keep_n_unions_recent_refs() {
        let dir = tmp("gc_keep");
        std::fs::remove_dir_all(&dir).ok();
        let mut store = RunStore::open(&dir).unwrap();
        let a = plan("a", 40, 1);
        let b = plan("b", 40, 2);
        store.store_run(&a.digest(), &result("a"), None).unwrap();
        store.store_run(&b.digest(), &result("b"), None).unwrap();
        store.record_refs([a.digest().as_str()], []).unwrap();
        store.record_refs([b.digest().as_str()], []).unwrap();
        // keep=2 unions both sweeps' refs: nothing to collect.
        let report = store.gc(false, 2).unwrap();
        assert!(report.collected_runs.is_empty());
        assert!(store.has_run(&a.digest()) && store.has_run(&b.digest()));
        // keep=1 keeps only the latest sweep's refs.
        let report = store.gc(false, 1).unwrap();
        assert_eq!(report.collected_runs, vec![a.digest()]);
        assert!(!store.has_run(&a.digest()) && store.has_run(&b.digest()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_entry_codec_roundtrips_through_plain_bytes() {
        // The wire form is the file format: encode to a Vec, decode back.
        let res = result("orig");
        let st = state();
        let mut bytes = Vec::new();
        write_run_entry(&mut bytes, &res, Some(&st)).unwrap();
        let (back, bstate) = read_run_entry(&mut &bytes[..], "renamed", true).unwrap();
        assert_eq!(back.curve.name, "renamed");
        assert_eq!(back.curve.points, res.curve.points);
        assert_eq!(back.layer_stats, res.layer_stats, "diagnostics rows must roundtrip");
        assert_eq!(back.ledger.total.to_bits(), res.ledger.total.to_bits());
        assert_eq!(bstate.unwrap().params[0].data, st.params[0].data);
        std::fs::remove_dir_all(tmp("unused")).ok();
    }
}
