//! Minimal JSON parser/serializer.
//!
//! The offline build environment pins the dependency closure of the `xla`
//! crate (no serde), so the manifest/config plumbing uses this self-contained
//! implementation. Supports the full JSON grammar; numbers are kept as f64
//! with an i64 fast path (shapes, counts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- serializer ------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: JSON from our own python writer
                            // never emits them for this project's data; map
                            // lone surrogates to REPLACEMENT.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let Some(c) = rest.chars().next() else {
                        return Err(self.err("invalid utf-8"));
                    };
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"shape":[4,64],"std":0.125,"name":"layer.0.attn.wq","muon":true}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }
}
