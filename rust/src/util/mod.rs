//! Shared substrates: JSON (offline build has no serde), deterministic RNG.
pub mod json;
pub mod proptest;
pub mod rng;
