//! Minimal property-based testing harness (the offline crate set has no
//! proptest): seeded random case generation with first-failure reporting.
//!
//! ```ignore
//! proptest(200, |g| {
//!     let n = g.usize(0..10);
//!     assert!(n < 10);
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        assert!(range.end > range.start);
        range.start + self.rng.below(range.end - range.start)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.uniform() as f32
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.uniform()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }
}

/// Run `cases` random cases of `prop`; panics with the failing case index and
/// seed so the failure is replayable.
pub fn proptest(cases: usize, mut prop: impl FnMut(&mut Gen)) {
    proptest_seeded(0xdeadbeef, cases, &mut prop);
}

pub fn proptest_seeded(seed: u64, cases: usize, prop: &mut impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let mut g = Gen { rng: Rng::new(seed.wrapping_add(case as u64 * 0x9e37)), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        proptest(50, |_| count += 1);
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn reports_failure() {
        proptest(50, |g| {
            let n = g.usize(0..100);
            assert!(n < 90, "n={n}");
        });
    }
}
