//! Deterministic RNG substrate (no external crates): splitmix64-seeded
//! xoshiro256++ with Box-Muller normals.
//!
//! Determinism matters twice here: (1) sweep replicates must be exactly
//! reproducible from (seed, param-name) so re-running a bench regenerates the
//! same curve; (2) new-layer *random* initialization at expansion time (the
//! paper's winning strategy for zero-layer sources) must be independent of
//! iteration order, so each parameter derives its own stream from a stable
//! name hash.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// FNV-1a: stable string hash for per-parameter substreams.
pub fn hash_name(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)], spare: None }
    }

    /// Independent stream for a named parameter under a run seed.
    pub fn for_param(seed: u64, name: &str) -> Self {
        Rng::new(seed ^ hash_name(name).rotate_left(17))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill a f32 buffer with N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * std;
        }
    }

    /// Zipf-like categorical sampler over n items, exponent `alpha`
    /// (inverse-CDF on precomputed weights is the caller's job; this is the
    /// cheap approximate variant used for corpus unigram draws).
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        // Inverse transform on the (approximate) continuous Zipf CDF.
        let u = self.uniform().max(1e-12);
        let x = ((n as f64).powf(1.0 - alpha) * u + (1.0 - u)).powf(1.0 / (1.0 - alpha));
        (x.floor() as usize).clamp(1, n) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn param_streams_differ() {
        let mut a = Rng::for_param(1, "layer.0.attn.wq");
        let mut b = Rng::for_param(1, "layer.1.attn.wq");
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 16];
        for _ in 0..10_000 {
            counts[r.zipf(16, 1.2)] += 1;
        }
        assert!(counts[0] > counts[8] * 3, "{counts:?}");
    }
}
