//! Integration tests for the contract-audit subsystem (`repro audit`).
//!
//! The codec tests deliberately bless into a scratch directory and check
//! against those fresh bytes rather than the committed fixtures under
//! `tests/golden/` — the committed vectors are enforced by the `repro
//! audit` CI job, while these tests pin the *machinery*: blessing is
//! idempotent, drift and missing fixtures fail with pointed diagnostics,
//! and a seeded byte mutation is caught.

use std::path::PathBuf;

use deep_progressive::audit::{codecs, fixtures, lint, model_check};
use deep_progressive::store::{digest_str, RunStore};

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dpt_audit_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ------------------------------------------------------------- codecs

#[test]
fn codecs_bless_then_check_is_clean() {
    let dir = scratch("bless");
    let blessed = codecs::run_codecs(&dir, true).unwrap();
    assert!(blessed.ok(), "bless run failed:\n{:#?}", blessed.checks);
    assert!(!blessed.blessed.is_empty());
    let checked = codecs::run_codecs(&dir, false).unwrap();
    assert!(
        checked.ok(),
        "freshly blessed fixtures should verify clean:\n{:#?}",
        checked
            .checks
            .iter()
            .filter(|c| !c.ok)
            .collect::<Vec<_>>()
    );
    // Every registry record and every wire frame has a fixture check, plus
    // per-record roundtrips and the version matrix.
    assert!(checked.checks.iter().any(|c| c.name == "versions" && c.ok));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn codecs_detect_seeded_byte_mutation() {
    let dir = scratch("drift");
    codecs::run_codecs(&dir, true).unwrap();
    // Seeded mutation: flip one byte in the middle of the plan fixture.
    let path = dir.join("plans.bin");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let rep = codecs::run_codecs(&dir, false).unwrap();
    assert!(!rep.ok());
    let bad = rep.checks.iter().find(|c| c.name == "plan").unwrap();
    assert!(!bad.ok);
    assert!(
        bad.detail.contains(&format!("byte drift at offset {mid}")),
        "diagnostic should carry the divergence offset: {}",
        bad.detail
    );
    assert!(bad.detail.contains("version bump"), "diagnostic: {}", bad.detail);
    // Only the mutated fixture fails; the other records still verify.
    assert!(rep.checks.iter().any(|c| c.name == "snapshot" && c.ok));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn codecs_missing_fixture_points_at_bless() {
    let dir = scratch("missing");
    let rep = codecs::run_codecs(&dir, false).unwrap();
    assert!(!rep.ok());
    let miss = rep.checks.iter().find(|c| c.name == "digest").unwrap();
    assert!(miss.detail.contains("--bless"), "diagnostic: {}", miss.detail);
    // Roundtrip and version checks run on live bytes and stay green even
    // with no fixtures on disk.
    assert!(rep.checks.iter().any(|c| c.name == "plan/roundtrip" && c.ok));
    assert!(rep.checks.iter().any(|c| c.name == "versions" && c.ok));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn committed_golden_dir_has_every_registry_fixture() {
    // The committed tree must carry one file per registry record and per
    // wire frame (byte equality itself is the CI audit job's assertion).
    let golden = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    for file in [
        "digest.txt",
        "plans.bin",
        "plan_desc.txt",
        "probe.txt",
        "snapshot.bin",
        "run_entry.bin",
        "journal.txt",
        "trace.txt",
        "wire_hello.bin",
        "wire_assign_trunk.bin",
        "wire_done_run.bin",
        "wire_shutdown.bin",
    ] {
        assert!(golden.join(file).is_file(), "missing committed fixture {file}");
    }
}

// -------------------------------------------------------------- lints

#[test]
fn lint_flags_hashmap_in_digest_path() {
    let src = "use std::collections::HashMap;\nfn f() -> HashMap<String, u32> { todo!() }\n";
    let (findings, _) = lint::scan_file_text("store/mod.rs", src);
    assert!(
        findings.iter().any(|f| f.lint == "map-iteration"),
        "HashMap in a digest-path module must be flagged: {findings:?}"
    );
    // The same code outside the lint's module class is clean.
    let (outside, _) = lint::scan_file_text("scaling/mod.rs", src);
    assert!(outside.iter().all(|f| f.lint != "map-iteration"), "{outside:?}");
}

#[test]
fn lint_allow_suppresses_and_is_inventoried() {
    let src = "fn f(m: &std::collections::HashMap<u8, u8>) {} \
               // audit:allow(map-iteration): type only, never iterated\n";
    let (findings, allows) = lint::scan_file_text("store/mod.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(allows.len(), 1);
    assert!(allows[0].used);
    assert_eq!(allows[0].lint, "map-iteration");
    assert_eq!(allows[0].reason, "type only, never iterated");
}

#[test]
fn lint_requires_reason_and_known_name() {
    let (findings, allows) =
        lint::scan_file_text("store/mod.rs", "// audit:allow(map-iteration):\nfn f() {}\n");
    assert!(allows.is_empty());
    assert!(findings.iter().any(|f| f.lint == "empty-allow-reason"), "{findings:?}");
    let (findings, _) =
        lint::scan_file_text("store/mod.rs", "// audit:allow(made-up-lint): because\n");
    assert!(findings.iter().any(|f| f.lint == "unknown-allow"), "{findings:?}");
}

#[test]
fn lint_skips_test_modules_and_strings() {
    let src = "fn live() {}\n\
               #[cfg(test)]\n\
               mod tests {\n    \
                   fn t() { let m = std::collections::HashMap::<u8, u8>::new(); m.len(); }\n\
               }\n";
    let (findings, _) = lint::scan_file_text("store/mod.rs", src);
    assert!(findings.is_empty(), "test modules are exempt: {findings:?}");
    let (findings, _) =
        lint::scan_file_text("store/mod.rs", "fn f() -> &'static str { \"HashMap\" }\n");
    assert!(findings.is_empty(), "string content must not fire code lints: {findings:?}");
}

#[test]
fn fix_allows_rewrites_bare_allows_idempotently() {
    let src = "    #[allow(dead_code)]\n    fn unused() {}\n";
    let (fixed, n) = lint::fix_allows_text(src);
    assert_eq!(n, 1);
    assert!(fixed.contains("// audit:allow(bare-allow):"), "{fixed}");
    // The inserted annotation matches the allow's indentation.
    assert!(fixed.starts_with("    // audit:allow(bare-allow):"), "{fixed}");
    let (again, n2) = lint::fix_allows_text(&fixed);
    assert_eq!(n2, 0, "fix must be idempotent");
    assert_eq!(again, fixed);
}

#[test]
fn repo_tree_is_lint_clean() {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let rep = lint::scan_dir(&src).unwrap();
    assert!(
        rep.ok(),
        "unsuppressed determinism-lint findings in the tree:\n{:#?}",
        rep.findings
    );
    // Every committed audit:allow must actually suppress something —
    // stale annotations are as misleading as missing ones.
    let unused: Vec<_> = rep.allows.iter().filter(|a| !a.used).collect();
    assert!(unused.is_empty(), "unused audit:allow annotations: {unused:#?}");
}

// ----------------------------------------- store ordering (regression)

#[test]
fn store_gc_and_compaction_emit_sorted_deterministic_order() {
    // Regression for the HashMap → BTreeMap conversions in the store:
    // whatever order entries are inserted, the dry-run GC report and the
    // compacted journal must come out digest-sorted.
    let dir = scratch("store_order");
    let salt = digest_str("audit-test-salt");
    let result = fixtures::fixture_result();
    let keys: Vec<String> =
        ["zeta", "alpha", "mid", "omega"].iter().map(|s| digest_str(s)).collect();
    let kept = keys[0].clone();
    {
        let mut st = RunStore::open_salted(&dir, &salt).unwrap();
        for k in &keys {
            st.store_run(k, &result, None).unwrap();
        }
        // Only the first inserted key is referenced; the rest are garbage.
        st.record_refs(std::iter::once(kept.as_str()), std::iter::empty()).unwrap();
        let report = st.gc(true, 1).unwrap();
        let mut expect: Vec<String> = keys.iter().filter(|k| **k != kept).cloned().collect();
        expect.sort();
        assert_eq!(report.collected_runs, expect, "dry-run GC must list digest-sorted");
        let report = st.gc(false, 1).unwrap();
        assert_eq!(report.collected_runs, expect);
        assert_eq!(report.live_runs, 1);
    }
    let journal =
        std::fs::read_to_string(dir.join(format!("ctx-{salt}")).join("journal.log")).unwrap();
    let runs: Vec<&str> = journal
        .lines()
        .filter_map(|l| l.strip_prefix("run "))
        .map(|l| l.split_whitespace().next().unwrap())
        .collect();
    assert_eq!(runs, vec![kept.as_str()], "only the referenced run survives compaction");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_compaction_orders_many_live_runs_by_digest() {
    let dir = scratch("store_sorted");
    let salt = digest_str("audit-test-salt-2");
    let result = fixtures::fixture_result();
    let keys: Vec<String> =
        ["k3", "k1", "k4", "k2"].iter().map(|s| digest_str(s)).collect();
    {
        let mut st = RunStore::open_salted(&dir, &salt).unwrap();
        for k in &keys {
            st.store_run(k, &result, None).unwrap();
        }
        st.record_refs(keys.iter().map(String::as_str), std::iter::empty()).unwrap();
        st.gc(false, 1).unwrap(); // compacts; everything is live
    }
    let journal =
        std::fs::read_to_string(dir.join(format!("ctx-{salt}")).join("journal.log")).unwrap();
    let runs: Vec<String> = journal
        .lines()
        .filter_map(|l| l.strip_prefix("run "))
        .map(|l| l.split_whitespace().next().unwrap().to_string())
        .collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(runs, sorted, "compacted journal must be digest-sorted");
    let _ = std::fs::remove_dir_all(&dir);
}

// -------------------------------------------------------- model check

#[test]
fn model_check_grids_are_order_insensitive() {
    let rep = model_check::run_model_check(200, 8, 17).unwrap();
    assert!(rep.ok(), "scheduler order-permutation check failed:\n{:#?}", rep.grids);
    assert_eq!(rep.grids.len(), 3);
    // The ladder grid is small enough to enumerate exhaustively; the wide
    // grid must have hit the budget and fallen back to sampling.
    assert!(rep.grids.iter().any(|g| g.exhaustive));
    assert!(rep.grids.iter().any(|g| !g.exhaustive));
    for g in &rep.grids {
        assert!(g.explored >= 1);
        assert!(!g.fingerprint.is_empty());
    }
}

#[test]
fn model_check_is_deterministic_across_invocations() {
    let a = model_check::run_model_check(50, 4, 17).unwrap();
    let b = model_check::run_model_check(50, 4, 17).unwrap();
    let fa: Vec<&str> = a.grids.iter().map(|g| g.fingerprint.as_str()).collect();
    let fb: Vec<&str> = b.grids.iter().map(|g| g.fingerprint.as_str()).collect();
    assert_eq!(fa, fb, "model-check fingerprints must be reproducible");
}
