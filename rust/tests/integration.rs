//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts` to have run (they are skipped with a clear
//! message otherwise, so `cargo test` stays green on a fresh checkout).

use deep_progressive::coordinator::{recipe, RunBuilder, RunDriver, Sweep, Trainer, TransferRule};
use deep_progressive::data::{Corpus, CorpusConfig};
use deep_progressive::expansion::{expand, CopyOrder, ExpandSpec, OsPolicy, Strategy};
use deep_progressive::flops::flops_per_step;
use deep_progressive::metrics::mixing_point;
use deep_progressive::runtime::{Engine, IntTensor, Manifest, ModelState};
use deep_progressive::schedule::Schedule;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&root) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

fn small_corpus() -> Corpus {
    Corpus::generate(CorpusConfig {
        vocab: 512,
        train_tokens: 200_000,
        val_tokens: 20_000,
        ..Default::default()
    })
}

#[test]
fn tensor_literal_roundtrip_is_bit_exact() {
    // The single-copy from_literal path (no shape re-validation) must
    // preserve bytes exactly; no artifacts needed, just the xla host API.
    let t = deep_progressive::runtime::Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
    let lit = t.to_literal().unwrap();
    let back = deep_progressive::runtime::Tensor::from_literal(&lit, &[2, 3]).unwrap();
    assert_eq!(t, back);
}

#[test]
fn train_step_learns() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let entry = m.get("gpt2.l1").unwrap();
    let mut state = ModelState::init(entry, 0);
    let mut batcher = deep_progressive::data::Batcher::new(&corpus.train, entry.model.seq_len, 3);
    let b = entry.model.batch;
    let s = entry.model.seq_len;
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..80 {
        let (x, y) = batcher.next_batch(b);
        let x = IntTensor::from_vec(&[b, s], x).unwrap();
        let y = IntTensor::from_vec(&[b, s], y).unwrap();
        last = engine
            .train_step(entry, &m.root, &mut state, &x, &y, 0.01, None)
            .unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last < first - 0.05, "loss did not decrease: {first} -> {last}");
}

#[test]
fn chunk_matches_single_steps() {
    // The fused K-step artifact must produce the same final state as K
    // single-step dispatches on the same data (the hot path is a pure
    // batching optimization, not a semantic change).
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let entry = m.get("gpt2.l0").unwrap();
    let b = entry.model.batch;
    let s = entry.model.seq_len;
    let k = entry.chunk;

    let mut batcher = deep_progressive::data::Batcher::new(&corpus.train, s, 5);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut batches = Vec::new();
    for _ in 0..k {
        let (x, y) = batcher.next_batch(b);
        xs.extend_from_slice(&x);
        ys.extend_from_slice(&y);
        batches.push((x, y));
    }
    let lrs: Vec<f32> = (0..k).map(|i| 0.005 + 0.001 * i as f32).collect();

    let mut st_chunk = ModelState::init(entry, 9);
    let xs_t = IntTensor::from_vec(&[k, b, s], xs).unwrap();
    let ys_t = IntTensor::from_vec(&[k, b, s], ys).unwrap();
    let losses = engine
        .train_chunk(entry, &m.root, &mut st_chunk, &xs_t, &ys_t, &lrs, None)
        .unwrap();
    assert_eq!(losses.len(), k);

    let mut st_single = ModelState::init(entry, 9);
    let mut single_losses = Vec::new();
    for (i, (x, y)) in batches.iter().enumerate() {
        let x = IntTensor::from_vec(&[b, s], x.clone()).unwrap();
        let y = IntTensor::from_vec(&[b, s], y.clone()).unwrap();
        single_losses.push(
            engine
                .train_step(entry, &m.root, &mut st_single, &x, &y, lrs[i], None)
                .unwrap(),
        );
    }
    for (a, b_) in losses.iter().zip(&single_losses) {
        assert!((a - b_).abs() < 1e-4, "chunk loss {a} vs single {b_}");
    }
    for (a, b_) in st_chunk.params.iter().zip(&st_single.params) {
        let maxdiff = a
            .data
            .iter()
            .zip(&b_.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(maxdiff < 1e-4, "params diverged: {maxdiff}");
    }
}

#[test]
fn zero_and_copying_zero_l_are_function_preserving() {
    // Takeaway 2 / §A.2: zero and copying_zeroL expansions must leave the
    // validation loss exactly unchanged (block outputs vanish).
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let src = m.get("gpt2.l1").unwrap();
    let dst = m.get("gpt2.l3").unwrap();
    let state = ModelState::init(src, 4);
    let b = src.model.batch;
    let s = src.model.seq_len;
    let mut batcher = deep_progressive::data::Batcher::new(&corpus.val, s, 1);
    let (x, y) = batcher.next_batch(b);
    let x = IntTensor::from_vec(&[b, s], x).unwrap();
    let y = IntTensor::from_vec(&[b, s], y).unwrap();
    let base = engine.eval_step(src, &m.root, &state, &x, &y, None).unwrap();

    for strategy in [Strategy::Zero, Strategy::CopyingZeroL] {
        let spec = ExpandSpec { strategy, ..Default::default() };
        let big = expand(src, dst, &state, &spec).unwrap();
        let loss = engine.eval_step(dst, &m.root, &big, &x, &y, None).unwrap();
        assert!(
            (loss - base).abs() < 5e-4,
            "{strategy:?} not function-preserving: {base} -> {loss}"
        );
    }

    // Copying (no zeroing) must NOT be function-preserving in general.
    let spec = ExpandSpec { strategy: Strategy::Copying(CopyOrder::Stack), ..Default::default() };
    let big = expand(src, dst, &state, &spec).unwrap();
    let loss = engine.eval_step(dst, &m.root, &big, &x, &y, None).unwrap();
    assert!((loss - base).abs() > 1e-3, "copying unexpectedly preserved the function");
}

#[test]
fn expansion_preserves_old_layer_bytes() {
    let Some(m) = manifest() else { return };
    let src = m.get("gpt2.l2").unwrap();
    let dst = m.get("gpt2.l6").unwrap();
    let state = ModelState::init(src, 11);
    let spec = ExpandSpec { strategy: Strategy::Random, os_policy: OsPolicy::Inherit, ..Default::default() };
    let big = expand(src, dst, &state, &spec).unwrap();
    // Old layers 0..2 and non-layer params must be bit-identical.
    for (i, pspec) in dst.params.iter().enumerate() {
        let keep = match pspec.layer_index() {
            None => true,
            Some(j) => j < 2,
        };
        if keep {
            let src_t = state.param(src, &pspec.name).unwrap();
            assert_eq!(src_t.data, big.params[i].data, "{} changed", pspec.name);
        }
    }
}

fn run_plan(
    trainer: Trainer,
    plan: deep_progressive::coordinator::RunPlan,
) -> deep_progressive::coordinator::RunResult {
    let mut d = RunDriver::new(trainer, plan).unwrap();
    d.run_to_end().unwrap();
    d.finish()
}

#[test]
fn progressive_run_end_to_end_mixes() {
    // Miniature Fig-3: zero-layer -> 3-layer progressive under constant LR
    // mixes with the fixed-size 3-layer run.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let trainer = Trainer::new(&engine, &m, &corpus);
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let total = 240;

    let fixed = run_plan(trainer, RunBuilder::fixed("fixed-l3", "gpt2.l3", total, sched).build().unwrap());
    let prog = run_plan(
        trainer,
        RunBuilder::progressive("prog-l0-l3", "gpt2.l0", "gpt2.l3", 48, total, sched, ExpandSpec::default())
            .build()
            .unwrap(),
    );

    assert_eq!(prog.boundaries.len(), 1);
    // The progressive run costs less compute...
    assert!(prog.ledger.total < fixed.ledger.total * 0.95);
    // ...and its loss approaches the fixed run's (generous tolerance at this
    // tiny scale: within 5% by the end or formally mixed).
    let gap = (prog.final_val_loss - fixed.final_val_loss) / fixed.final_val_loss;
    let mixed = mixing_point(&prog.curve, &fixed.curve, 0.05, 2).is_some();
    assert!(mixed || gap < 0.05, "gap {gap}, mixed {mixed}");
}

#[test]
fn device_path_matches_host_materialized_reference() {
    // Acceptance (device-resident runtime): the buffer-threading hot path
    // must be a pure transport optimization. A run whose engine is forced to
    // materialize the full state to host tensors and re-upload it after
    // EVERY dispatch unit (the pre-refactor behavior) must produce
    // bit-identical loss curves and a bit-identical final model state.
    let Some(m) = manifest() else { return };
    let corpus = small_corpus();
    let sched = Schedule::Wsd { peak: 0.01, warmup_frac: 0.02, decay_frac: 0.2 };
    let plan = RunBuilder::progressive("equiv", "gpt2.l0", "gpt2.l3", 40, 120, sched, ExpandSpec::default())
        .eval_every(20)
        .build()
        .unwrap();

    let run = |host_roundtrip: bool| {
        let engine = Engine::cpu().unwrap();
        engine.set_host_roundtrip(host_roundtrip);
        let trainer = Trainer::new(&engine, &m, &corpus);
        let mut d = RunDriver::new(trainer, plan.clone()).unwrap();
        d.run_to_end().unwrap();
        let state = d.state().unwrap();
        (d.finish(), state)
    };
    let (dev_res, dev_state) = run(false);
    let (ref_res, ref_state) = run(true);

    assert_eq!(dev_res.curve.points.len(), ref_res.curve.points.len());
    for (a, b) in dev_res.curve.points.iter().zip(&ref_res.curve.points) {
        assert_eq!(a, b, "device-resident curve diverged from host-materialized reference");
    }
    assert_eq!(dev_res.boundaries, ref_res.boundaries);
    for (a, b) in dev_state.params.iter().zip(&ref_state.params) {
        assert_eq!(a.data, b.data, "final params diverged between transport paths");
    }
    for (a, b) in dev_state.opt.iter().zip(&ref_state.opt) {
        assert_eq!(a.data, b.data, "final optimizer state diverged between transport paths");
    }
}

#[test]
fn curve_has_single_point_per_step_except_boundaries() {
    // Regression (duplicate curve point): when a stage boundary coincides
    // with the eval cadence, the old loop pushed a cadence eval AND the
    // boundary's pre-eval at the same step. The curve must be non-decreasing
    // in step, with exactly two points (pre/post) at each boundary and one
    // everywhere else.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let trainer = Trainer::new(&engine, &m, &corpus);
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let total = 96;
    let tau = 48; // multiple of eval_every below: the old code duplicated here
    let plan = RunBuilder::progressive("dup", "gpt2.l0", "gpt2.l3", tau, total, sched, ExpandSpec::default())
        .eval_every(24)
        .build()
        .unwrap();
    let res = run_plan(trainer, plan);

    let steps: Vec<usize> = res.curve.points.iter().map(|p| p.step).collect();
    for w in steps.windows(2) {
        assert!(w[1] >= w[0], "curve steps not monotone: {steps:?}");
    }
    let mut counts = std::collections::BTreeMap::new();
    for s in &steps {
        *counts.entry(*s).or_insert(0usize) += 1;
    }
    for (s, n) in counts {
        if s == tau {
            assert_eq!(n, 2, "boundary step {s} must log exactly pre+post, got {n}: {steps:?}");
        } else {
            assert_eq!(n, 1, "step {s} logged {n} times: {steps:?}");
        }
    }
}

#[test]
fn deterministic_pause_snapshot_resume() {
    // Acceptance: a driver paused mid-run, checkpointed to disk, reloaded,
    // and resumed produces a bit-identical loss curve and final state to an
    // uninterrupted run of the same plan — with the device-resident state in
    // the loop (the snapshot materializes device buffers; the resume
    // re-uploads them). Exercised both mid-stage-0 and past the expansion
    // boundary (stage 1, after a StageExec rebind + re-upload).
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let trainer = Trainer::new(&engine, &m, &corpus);
    let sched = Schedule::Wsd { peak: 0.01, warmup_frac: 0.02, decay_frac: 0.2 };
    let plan = RunBuilder::progressive("resume", "gpt2.l0", "gpt2.l3", 60, 120, sched, ExpandSpec::default())
        .eval_every(20)
        .build()
        .unwrap();

    // Uninterrupted reference.
    let mut ref_d = RunDriver::new(trainer, plan.clone()).unwrap();
    ref_d.run_to_end().unwrap();
    let ref_state = ref_d.state().unwrap();
    let reference = ref_d.finish();

    let dir = std::env::temp_dir().join(format!("dpt_resume_{}", std::process::id()));
    for pause_budget in [50usize, 80] {
        // Paused run: stop (mid-stage-0 / mid-stage-1), snapshot to disk,
        // reload, resume.
        let mut d = RunDriver::new(trainer, plan.clone()).unwrap();
        let taken = d.advance(pause_budget).unwrap();
        assert!(taken > 0 && !d.is_done());
        let path = dir.join(format!("mid-{pause_budget}.snap"));
        d.save_snapshot(&path).unwrap();
        drop(d);

        let cfg = deep_progressive::checkpoint::snapshot_cfg_id(&path).unwrap();
        let snap = deep_progressive::checkpoint::load_snapshot(&path, m.get(&cfg).unwrap()).unwrap();
        assert_eq!(snap.step, taken);
        let mut resumed_d = RunDriver::resume(trainer, plan.clone(), snap).unwrap();
        resumed_d.run_to_end().unwrap();
        let resumed_state = resumed_d.state().unwrap();
        let resumed = resumed_d.finish();

        assert_eq!(reference.curve.points.len(), resumed.curve.points.len());
        for (a, b) in reference.curve.points.iter().zip(&resumed.curve.points) {
            assert_eq!(a, b, "resumed curve diverged from uninterrupted run (pause {pause_budget})");
        }
        assert_eq!(reference.boundaries, resumed.boundaries);
        assert_eq!(reference.ledger.tokens, resumed.ledger.tokens);
        for (a, b) in ref_state.params.iter().zip(&resumed_state.params) {
            assert_eq!(a.data, b.data, "final params diverged after resume (pause {pause_budget})");
        }
        for (a, b) in ref_state.opt.iter().zip(&resumed_state.opt) {
            assert_eq!(a.data, b.data, "final optimizer state diverged after resume (pause {pause_budget})");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_shares_source_model_training() {
    // Acceptance: a two-variant expansion sweep performs the small-model
    // training steps once — asserted via the FLOP ledger.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let trainer = Trainer::new(&engine, &m, &corpus);
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let (total, tau) = (120, 40);
    let mk = |name: &str, strategy: Strategy| {
        RunBuilder::progressive(
            name,
            "gpt2.l0",
            "gpt2.l3",
            tau,
            total,
            sched,
            ExpandSpec { strategy, ..Default::default() },
        )
        .build()
        .unwrap()
    };
    let mut sweep = Sweep::new(trainer);
    sweep.add(mk("variant-random", Strategy::Random));
    sweep.add(mk("variant-zero", Strategy::Zero));
    let outcome = sweep.run().unwrap();
    assert_eq!(outcome.results.len(), 2);

    // Each per-run ledger represents the full run (prefix included)...
    let small = m.get("gpt2.l0").unwrap();
    let prefix_flops = flops_per_step(small) * tau as f64;
    for res in &outcome.results {
        assert_eq!(res.boundaries.len(), 1);
        assert!(res.ledger.total > prefix_flops);
    }
    // ...but the executed total counts the shared prefix exactly once.
    let represented: f64 = outcome.results.iter().map(|r| r.ledger.total).sum();
    assert!((outcome.shared_flops - prefix_flops).abs() / prefix_flops < 1e-9);
    assert!(
        (outcome.executed_flops - (represented - prefix_flops)).abs() / represented < 1e-9,
        "executed {} represented {} prefix {}",
        outcome.executed_flops,
        represented,
        prefix_flops
    );
    // And the shared trunk did not change the result: a standalone run of
    // the same plan is bit-identical.
    let standalone = run_plan(trainer, mk("variant-random", Strategy::Random));
    assert_eq!(standalone.curve.points.len(), outcome.results[0].curve.points.len());
    for (a, b) in standalone.curve.points.iter().zip(&outcome.results[0].curve.points) {
        assert_eq!(a, b, "sweep-forked run diverged from standalone");
    }
}

#[test]
fn parallel_sweep_is_bit_identical_to_serial() {
    // Acceptance (parallel execution subsystem): a fig-3-style grid — one
    // fixed baseline plus a shared-trunk strategy group — executed over the
    // 2-worker engine pool must reproduce the serial sweep exactly: curves,
    // boundaries, per-run ledgers, final model states, and the
    // executed/shared FLOP totals, all bit-identical.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let trainer = Trainer::new(&engine, &m, &corpus);
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let (total, tau) = (120, 40);
    let plans = || {
        let mut v =
            vec![RunBuilder::fixed("par-fixed", "gpt2.l3", total, sched).build().unwrap()];
        for (name, strategy) in [("random", Strategy::Random), ("zero", Strategy::Zero)] {
            v.push(
                RunBuilder::progressive(
                    format!("par-{name}"),
                    "gpt2.l0",
                    "gpt2.l3",
                    tau,
                    total,
                    sched,
                    ExpandSpec { strategy, ..Default::default() },
                )
                .build()
                .unwrap(),
            );
        }
        v
    };
    let run = |workers: usize| {
        let mut sweep = Sweep::new(trainer);
        sweep.keep_final_states(true);
        for p in plans() {
            sweep.add(p);
        }
        sweep.run_parallel(workers).unwrap()
    };
    let serial = run(1); // run_parallel(1) delegates to the serial path
    let par = run(2);

    assert_eq!(serial.results.len(), par.results.len());
    assert_eq!(
        serial.executed_flops.to_bits(),
        par.executed_flops.to_bits(),
        "executed FLOPs diverged: {} vs {}",
        serial.executed_flops,
        par.executed_flops
    );
    assert_eq!(serial.shared_flops.to_bits(), par.shared_flops.to_bits());
    for (a, b) in serial.results.iter().zip(&par.results) {
        assert_eq!(a.curve.name, b.curve.name, "result order changed");
        assert_eq!(a.curve.points.len(), b.curve.points.len());
        for (p, q) in a.curve.points.iter().zip(&b.curve.points) {
            assert_eq!(p, q, "curve diverged under parallel execution ('{}')", a.curve.name);
        }
        assert_eq!(a.boundaries, b.boundaries);
        assert_eq!(a.ledger.total.to_bits(), b.ledger.total.to_bits());
        assert_eq!(a.ledger.tokens, b.ledger.tokens);
        assert_eq!(a.final_val_loss.to_bits(), b.final_val_loss.to_bits());
    }
    for (i, (a, b)) in serial.final_states.iter().zip(&par.final_states).enumerate() {
        let (a, b) = (a.as_ref().expect("kept state"), b.as_ref().expect("kept state"));
        for (x, y) in a.params.iter().zip(&b.params) {
            assert_eq!(x.data, y.data, "final params diverged under parallel execution (run {i})");
        }
        for (x, y) in a.opt.iter().zip(&b.opt) {
            assert_eq!(x.data, y.data, "final opt state diverged under parallel execution (run {i})");
        }
    }
}

#[test]
fn interrupted_sweep_resumes_bit_identical_from_store() {
    // Acceptance (durable sweep store, DESIGN.md §7): a sweep killed partway
    // — simulated by running only half the grid against a store, exactly the
    // journal + cache state a crash after those jobs leaves behind — must
    // resume re-running only unfinished jobs and produce curves, final
    // states, and executed/shared FLOP totals bit-identical to an
    // uninterrupted run, at 1 and 4 workers. A fully warm rerun must execute
    // zero dispatches.
    use deep_progressive::coordinator::{RunPlan, SweepOutcome};

    let Some(m) = manifest() else { return };
    let corpus = small_corpus();
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let (total, tau) = (120, 40);
    // A 1-layer source: the grid includes a Copying variant, which is
    // Table-2-inapplicable to a 0-layer source (the plan vet rejects it).
    let prog = |name: &str, strategy: Strategy| {
        RunBuilder::progressive(
            name,
            "gpt2.l1",
            "gpt2.l3",
            tau,
            total,
            sched,
            ExpandSpec { strategy, ..Default::default() },
        )
        .build()
        .unwrap()
    };
    // One standalone baseline + a three-variant shared-trunk group.
    let full_grid = || -> Vec<RunPlan> {
        vec![
            RunBuilder::fixed("st-fixed", "gpt2.l3", total, sched).build().unwrap(),
            prog("st-random", Strategy::Random),
            prog("st-zero", Strategy::Zero),
            prog("st-copying", Strategy::Copying(CopyOrder::Stack)),
        ]
    };
    let half_grid = || full_grid().into_iter().take(2).collect::<Vec<_>>();

    // Returns (outcome, caller-engine dispatches, progress bytes). The
    // caller engine only sees serial work; the progress capture sees every
    // executing driver on *any* worker count (pool workers attach printers
    // too), so "zero progress bytes" means no job trained or evaluated.
    let run = |store_dir: Option<&std::path::Path>, plans: Vec<RunPlan>, workers: usize| {
        use deep_progressive::coordinator::ProgressSink;
        let engine = Engine::cpu().unwrap();
        let trainer = Trainer::new(&engine, &m, &corpus);
        let mut sweep = Sweep::new(trainer);
        sweep.keep_final_states(true);
        let (sink, captured) = ProgressSink::capture();
        sweep.progress(sink);
        if let Some(dir) = store_dir {
            sweep.store(dir).unwrap();
        }
        for p in plans {
            sweep.add(p);
        }
        let outcome = if workers <= 1 {
            sweep.run().unwrap()
        } else {
            sweep.run_parallel(workers).unwrap()
        };
        let progress_bytes = captured.lock().unwrap().len();
        (outcome, engine.stats().dispatches, progress_bytes)
    };

    let assert_outcomes_identical = |a: &SweepOutcome, b: &SweepOutcome, what: &str| {
        assert_eq!(a.results.len(), b.results.len(), "{what}: result count");
        assert_eq!(a.executed_flops.to_bits(), b.executed_flops.to_bits(), "{what}: executed_flops");
        assert_eq!(a.shared_flops.to_bits(), b.shared_flops.to_bits(), "{what}: shared_flops");
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.curve.name, y.curve.name, "{what}: result order/name");
            assert_eq!(x.curve.points.len(), y.curve.points.len(), "{what}: curve length");
            for (p, q) in x.curve.points.iter().zip(&y.curve.points) {
                assert_eq!(p, q, "{what}: curve diverged ('{}')", x.curve.name);
            }
            assert_eq!(x.boundaries, y.boundaries, "{what}: boundaries");
            assert_eq!(x.ledger.total.to_bits(), y.ledger.total.to_bits(), "{what}: ledger");
            assert_eq!(x.ledger.tokens, y.ledger.tokens, "{what}: tokens");
            assert_eq!(x.final_val_loss.to_bits(), y.final_val_loss.to_bits(), "{what}: final loss");
        }
        for (i, (x, y)) in a.final_states.iter().zip(&b.final_states).enumerate() {
            let (x, y) = (x.as_ref().expect("kept state"), y.as_ref().expect("kept state"));
            for (s, t) in x.params.iter().zip(&y.params) {
                assert_eq!(s.data, t.data, "{what}: final params diverged (run {i})");
            }
            for (s, t) in x.opt.iter().zip(&y.opt) {
                assert_eq!(s.data, t.data, "{what}: final opt state diverged (run {i})");
            }
        }
    };

    // Uninterrupted reference (no store anywhere near it). Sanity-check
    // that the progress capture actually observes executing runs, so the
    // zero-bytes assertions below cannot pass vacuously.
    let (reference, _, ref_progress) = run(None, full_grid(), 1);
    assert!(ref_progress > 0, "progress capture must see executed runs");

    for workers in [1usize, 4] {
        let dir = std::env::temp_dir()
            .join(format!("dpt_sweep_store_w{workers}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        // "Kill" after half the grid: these jobs are journaled + cached.
        run(Some(&dir), half_grid(), 1);
        // Resume with the full grid: trunk + finished runs come from the
        // store, only unfinished variants execute.
        let (resumed, _, _) = run(Some(&dir), full_grid(), workers);
        assert_outcomes_identical(&reference, &resumed, &format!("resumed at {workers} workers"));
        // Warm rerun: everything cached — nothing trains or evaluates, on
        // the caller's engine (serial) or any pool worker's (progress).
        let (warm, dispatches, progress) = run(Some(&dir), full_grid(), workers);
        assert_outcomes_identical(&reference, &warm, &format!("warm rerun at {workers} workers"));
        assert_eq!(dispatches, 0, "warm-store rerun must execute zero dispatches");
        assert_eq!(progress, 0, "warm-store rerun must run no driver at {workers} workers");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn ladder_sweep_parallel_and_warm_store_bit_identical_to_serial_cold() {
    // Acceptance (multi-round depth ladders): a 3-round ladder grid — two
    // ladder variants sharing every rung trunk (they differ only in the
    // final round's LR re-warm), a FLOP-comparable one-shot expansion, and
    // a fixed-depth baseline — executed (a) serially with no store, (b) at
    // 2 workers populating a store, and (c) at 4 workers against the now-
    // warm store, must produce bit-identical curves, final model states,
    // and executed/shared FLOP totals in all three modes. The warm pass
    // must train nothing.
    use deep_progressive::coordinator::{LadderRound, ProgressSink, RunPlan, SweepOutcome};

    let Some(m) = manifest() else { return };
    let corpus = small_corpus();
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let total = 160;
    let taus = [40usize, 80, 120];
    let ladder = |name: &str, last_rewarm: usize| -> RunPlan {
        let rounds = vec![
            LadderRound::new("gpt2.l1", taus[0], ExpandSpec::default()),
            LadderRound::new("gpt2.l2", taus[1], ExpandSpec::default()),
            LadderRound::new("gpt2.l3", taus[2], ExpandSpec::default()).rewarm(last_rewarm),
        ];
        RunBuilder::ladder(name, "gpt2.l0", &rounds, total, sched)
            .eval_every(20)
            .build()
            .unwrap()
    };
    let grid = || -> Vec<RunPlan> {
        vec![
            ladder("lad-plain", 0),
            ladder("lad-rewarm", 8),
            RunBuilder::progressive(
                "lad-oneshot",
                "gpt2.l0",
                "gpt2.l3",
                taus[2],
                total,
                sched,
                ExpandSpec::default(),
            )
            .eval_every(20)
            .build()
            .unwrap(),
            RunBuilder::fixed("lad-fixed", "gpt2.l3", total, sched).eval_every(20).build().unwrap(),
        ]
    };

    let run = |store_dir: Option<&std::path::Path>, workers: usize| {
        let engine = Engine::cpu().unwrap();
        let trainer = Trainer::new(&engine, &m, &corpus);
        let mut sweep = Sweep::new(trainer);
        sweep.keep_final_states(true);
        let (sink, captured) = ProgressSink::capture();
        sweep.progress(sink);
        if let Some(dir) = store_dir {
            sweep.store(dir).unwrap();
        }
        for p in grid() {
            sweep.add(p);
        }
        let outcome =
            if workers <= 1 { sweep.run().unwrap() } else { sweep.run_parallel(workers).unwrap() };
        let progress_bytes = captured.lock().unwrap().len();
        (outcome, engine.stats().dispatches, progress_bytes)
    };

    let assert_identical = |a: &SweepOutcome, b: &SweepOutcome, what: &str| {
        assert_eq!(a.results.len(), b.results.len(), "{what}: result count");
        assert_eq!(a.executed_flops.to_bits(), b.executed_flops.to_bits(), "{what}: executed_flops");
        assert_eq!(a.shared_flops.to_bits(), b.shared_flops.to_bits(), "{what}: shared_flops");
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.curve.name, y.curve.name, "{what}: result order");
            assert_eq!(x.curve.points.len(), y.curve.points.len(), "{what}: curve length");
            for (p, q) in x.curve.points.iter().zip(&y.curve.points) {
                assert_eq!(p, q, "{what}: curve diverged ('{}')", x.curve.name);
            }
            assert_eq!(x.boundaries, y.boundaries, "{what}: boundaries");
            assert_eq!(x.ledger.total.to_bits(), y.ledger.total.to_bits(), "{what}: ledger");
            assert_eq!(x.final_val_loss.to_bits(), y.final_val_loss.to_bits(), "{what}: final loss");
        }
        for (i, (x, y)) in a.final_states.iter().zip(&b.final_states).enumerate() {
            let (x, y) = (x.as_ref().expect("kept state"), y.as_ref().expect("kept state"));
            for (s, t) in x.params.iter().zip(&y.params) {
                assert_eq!(s.data, t.data, "{what}: final params diverged (run {i})");
            }
            for (s, t) in x.opt.iter().zip(&y.opt) {
                assert_eq!(s.data, t.data, "{what}: final opt state diverged (run {i})");
            }
        }
    };

    // (a) Serial cold reference, no store.
    let (reference, _, ref_progress) = run(None, 1);
    assert!(ref_progress > 0, "progress capture must observe executing runs");
    // Both ladder variants carry all three boundaries; the rung segments
    // were shared (executed < represented).
    for res in &reference.results[..2] {
        assert_eq!(
            res.boundaries.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
            taus.to_vec(),
            "ladder must cross all three boundaries"
        );
    }
    assert!(reference.shared_flops > 0.0, "ladder rungs must be shared");

    // (b) 2 workers, cold store: populates runs + every rung trunk.
    let dir = std::env::temp_dir().join(format!("dpt_ladder_store_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let (populated, _, _) = run(Some(&dir), 2);
    assert_identical(&reference, &populated, "2-worker cold-store run");

    // (c) 4 workers, warm store: identical outcome, zero training.
    let (warm, dispatches, progress) = run(Some(&dir), 4);
    assert_identical(&reference, &warm, "4-worker warm-store run");
    assert_eq!(dispatches, 0, "warm rerun must execute zero dispatches on the caller engine");
    assert_eq!(progress, 0, "warm rerun must run no driver on any worker");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fabric_ladder_grid_bit_identical_to_serial_even_after_worker_loss() {
    // Acceptance (distributed sweep fabric, DESIGN.md §9): a multi-round
    // ladder grid executed by a coordinator + two real `repro worker`
    // subprocesses over loopback TCP — one of which defects after a single
    // job (`--max-jobs 1`), forcing dead-worker reassignment — must
    // assemble curves, final model states, and executed/shared FLOP totals
    // bit-identical to the serial sweep. A second coordinator run against
    // the now-warm shared store must dispatch zero jobs.
    use deep_progressive::coordinator::{LadderRound, RunPlan, SweepOutcome};
    use deep_progressive::exec::JobGraph;
    use deep_progressive::fabric::{FabricOptions, FabricServer};
    use deep_progressive::store::RunStore;
    use std::process::{Child, Command, Stdio};

    let Some(m) = manifest() else { return };
    // Must match the corpus `repro worker` builds for itself, or the
    // handshake's context salt rightly refuses the fleet.
    let corpus = Corpus::generate(CorpusConfig::default());
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let total = 160;
    let taus = [40usize, 80, 120];
    let ladder = |name: &str, last_rewarm: usize| -> RunPlan {
        let rounds = vec![
            LadderRound::new("gpt2.l1", taus[0], ExpandSpec::default()),
            LadderRound::new("gpt2.l2", taus[1], ExpandSpec::default()),
            LadderRound::new("gpt2.l3", taus[2], ExpandSpec::default()).rewarm(last_rewarm),
        ];
        RunBuilder::ladder(name, "gpt2.l0", &rounds, total, sched)
            .eval_every(20)
            .build()
            .unwrap()
    };
    let grid = || -> Vec<RunPlan> {
        vec![
            ladder("fab-plain", 0),
            ladder("fab-rewarm", 8),
            RunBuilder::fixed("fab-fixed", "gpt2.l3", total, sched).eval_every(20).build().unwrap(),
        ]
    };

    let assert_identical = |a: &SweepOutcome, b: &SweepOutcome, what: &str| {
        assert_eq!(a.results.len(), b.results.len(), "{what}: result count");
        assert_eq!(a.executed_flops.to_bits(), b.executed_flops.to_bits(), "{what}: executed_flops");
        assert_eq!(a.shared_flops.to_bits(), b.shared_flops.to_bits(), "{what}: shared_flops");
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.curve.name, y.curve.name, "{what}: result order");
            assert_eq!(x.curve.points.len(), y.curve.points.len(), "{what}: curve length");
            for (p, q) in x.curve.points.iter().zip(&y.curve.points) {
                assert_eq!(p, q, "{what}: curve diverged ('{}')", x.curve.name);
            }
            assert_eq!(x.boundaries, y.boundaries, "{what}: boundaries");
            assert_eq!(x.ledger.total.to_bits(), y.ledger.total.to_bits(), "{what}: ledger");
            assert_eq!(x.final_val_loss.to_bits(), y.final_val_loss.to_bits(), "{what}: final loss");
        }
        for (i, (x, y)) in a.final_states.iter().zip(&b.final_states).enumerate() {
            let (x, y) = (x.as_ref().expect("kept state"), y.as_ref().expect("kept state"));
            for (s, t) in x.params.iter().zip(&y.params) {
                assert_eq!(s.data, t.data, "{what}: final params diverged (run {i})");
            }
            for (s, t) in x.opt.iter().zip(&y.opt) {
                assert_eq!(s.data, t.data, "{what}: final opt state diverged (run {i})");
            }
        }
    };

    // Serial reference: the caller's engine, no store, no network.
    let reference = {
        let engine = Engine::cpu().unwrap();
        let trainer = Trainer::new(&engine, &m, &corpus);
        let mut sweep = Sweep::new(trainer);
        sweep.keep_final_states(true);
        for p in grid() {
            sweep.add(p);
        }
        sweep.run().unwrap()
    };

    let artifacts_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let spawn_worker = |addr: &str, max_jobs: Option<usize>| -> Child {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_repro"));
        cmd.arg("worker")
            .arg("--artifacts")
            .arg(&artifacts_root)
            .arg("--connect")
            .arg(addr)
            .arg("--workers")
            .arg("2")
            .stdout(Stdio::null())
            .stderr(Stdio::null());
        if let Some(k) = max_jobs {
            cmd.arg("--max-jobs").arg(k.to_string());
        }
        cmd.spawn().expect("spawning a repro worker subprocess")
    };

    let dir = std::env::temp_dir().join(format!("dpt_fabric_store_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let salt = RunStore::context_salt(&m, &corpus);
    let graph = JobGraph::lower(grid()).unwrap();
    let opts = FabricOptions { keep_states: true, ..FabricOptions::default() };

    // Coordinator + 2 worker processes; the defector executes one job and
    // then drops its connection on the next assignment, like a crash.
    let server = FabricServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let mut defector = spawn_worker(&addr, Some(1));
    let mut survivor = spawn_worker(&addr, None);
    let mut store = RunStore::open_salted(&dir, &salt).unwrap();
    let (outcome, stats) = server.run(&m, &corpus, &graph, &opts, Some(&mut store)).unwrap();
    drop(store);
    assert!(defector.wait().unwrap().success(), "defecting worker must still exit cleanly");
    assert!(survivor.wait().unwrap().success(), "surviving worker must exit cleanly");

    assert_eq!(stats.connections, 2, "both workers must have connected");
    assert!(stats.workers_lost >= 1, "the defector must be declared lost: {stats:?}");
    assert!(stats.reassigned_jobs >= 1, "its jobs must be reassigned: {stats:?}");
    assert!(stats.remote_jobs >= 1, "remote slots must have executed jobs: {stats:?}");
    assert_eq!(stats.cached_jobs, 0, "first run starts from a cold store");
    assert_identical(&reference, &outcome, "fabric grid with a lost worker");

    // Warm shared repository: a fresh coordinator dispatches nothing.
    let server = FabricServer::bind("127.0.0.1:0").unwrap();
    let mut store = RunStore::open_salted(&dir, &salt).unwrap();
    let (warm, wstats) = server.run(&m, &corpus, &graph, &opts, Some(&mut store)).unwrap();
    assert_eq!(wstats.dispatched_jobs, 0, "warm rerun must dispatch zero jobs: {wstats:?}");
    assert_eq!(wstats.connections, 0, "a fully warm run never touches the network");
    assert_identical(&reference, &warm, "warm fabric rerun");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sigkilled_coordinator_resumes_bit_identical_with_reconnecting_workers() {
    // Acceptance (chaos-hardened fabric, DESIGN.md §9–10): a `repro serve`
    // coordinator SIGKILLed mid-ladder-grid and restarted with `--resume`
    // on the same address must rebuild its scheduler purely from the store
    // journal, re-handshake the surviving `repro worker` subprocesses (one
    // of which defects after a single job), dispatch only unfinished work,
    // and assemble an outcome bit-identical to the serial sweep. A second
    // fully-warm `--resume` must dispatch zero jobs and ship zero snapshot
    // bytes.
    use deep_progressive::coordinator::SweepOutcome;
    use deep_progressive::exec::JobGraph;
    use deep_progressive::fabric::{FabricOptions, FabricServer};
    use deep_progressive::store::RunStore;
    use std::io::BufRead;
    use std::process::{Child, Command, Stdio};
    use std::time::{Duration, Instant};

    let Some(m) = manifest() else { return };
    // Must match the corpus the subprocesses build for themselves, or the
    // handshake's context salt rightly refuses the fleet.
    let corpus = Corpus::generate(CorpusConfig::default());

    // The grid the `serve` CLI builds from these exact flags — via the
    // same recipe::ladder_grid the CLI delegates to, so the restarted
    // in-process coordinator resumes the identical plan set.
    let sched = Schedule::Wsd { peak: 0.01, warmup_frac: 0.02, decay_frac: 0.2 };
    let rungs = ["gpt2.l0", "gpt2.l1", "gpt2.l3"];
    let spec = recipe::LadderGridSpec {
        rungs: &rungs,
        steps: 160,
        seed: 17,
        sched,
        base: ExpandSpec::default(),
        rewarm: 0,
        taus: Some(vec![0.25, 0.5]),
        strategies: Some(vec!["random".into(), "zero".into()]),
        eval_every: Some(20),
        transfer: TransferRule::Fixed,
    };
    let plans = recipe::ladder_grid(&spec).unwrap();

    // Serial reference (no store, no network).
    let reference = {
        let engine = Engine::cpu().unwrap();
        let trainer = Trainer::new(&engine, &m, &corpus);
        let mut sweep = Sweep::new(trainer);
        for p in plans.clone() {
            sweep.add(p);
        }
        sweep.run().unwrap()
    };

    let assert_identical = |a: &SweepOutcome, b: &SweepOutcome, what: &str| {
        assert_eq!(a.results.len(), b.results.len(), "{what}: result count");
        assert_eq!(
            a.executed_flops.to_bits(),
            b.executed_flops.to_bits(),
            "{what}: executed_flops"
        );
        assert_eq!(a.shared_flops.to_bits(), b.shared_flops.to_bits(), "{what}: shared_flops");
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.curve.name, y.curve.name, "{what}: result order");
            assert_eq!(x.curve.points, y.curve.points, "{what}: curve ('{}')", x.curve.name);
            assert_eq!(x.boundaries, y.boundaries, "{what}: boundaries");
            assert_eq!(x.ledger.total.to_bits(), y.ledger.total.to_bits(), "{what}: ledger");
            assert_eq!(x.ledger.tokens, y.ledger.tokens, "{what}: tokens");
            assert_eq!(x.final_val_loss.to_bits(), y.final_val_loss.to_bits(), "{what}: loss");
        }
    };

    let artifacts_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let dir = std::env::temp_dir().join(format!("dpt_failover_store_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // Phase 1: a real `repro serve` subprocess on an ephemeral port (so it
    // can be SIGKILLed like a crashed host), with the grid flags above.
    let mut serve = Command::new(env!("CARGO_BIN_EXE_repro"));
    serve
        .arg("serve")
        .args(["--listen", "127.0.0.1:0", "--steps", "160", "--seed", "17"])
        .args(["--taus", "0.25,0.5", "--strategies", "random,zero", "--eval-every", "20"])
        .args(["--workers", "0"])
        .arg("--artifacts")
        .arg(&artifacts_root)
        .arg("--store-dir")
        .arg(&dir)
        .arg("--out")
        .arg(dir.join("csv-ignored"))
        .stdout(Stdio::piped())
        .stderr(Stdio::null());
    for r in &rungs {
        serve.arg(r);
    }
    let mut serve = serve.spawn().expect("spawning repro serve");
    let addr = {
        let out = serve.stdout.take().expect("serve stdout piped");
        let mut lines = std::io::BufReader::new(out).lines();
        let mut addr = None;
        for line in &mut lines {
            let line = line.expect("reading serve stdout");
            if let Some(rest) = line.strip_prefix("fabric coordinator listening on ") {
                addr = Some(rest.trim().to_string());
                break;
            }
        }
        // Keep draining stdout so the coordinator can never block on a
        // full pipe while we are busy elsewhere.
        std::thread::spawn(move || for _ in lines {});
        addr.expect("serve never announced its address")
    };

    let spawn_worker = |max_jobs: Option<usize>| -> Child {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_repro"));
        cmd.arg("worker")
            .arg("--artifacts")
            .arg(&artifacts_root)
            .args(["--connect", &addr, "--workers", "1"])
            .args(["--retry-max", "20", "--retry-base", "250"])
            .stdout(Stdio::null())
            .stderr(Stdio::null());
        if let Some(k) = max_jobs {
            cmd.arg("--max-jobs").arg(k.to_string());
        }
        cmd.spawn().expect("spawning a repro worker subprocess")
    };
    let mut defector = spawn_worker(Some(1));
    let mut survivor = spawn_worker(None);

    // Wait for the first trunk commit to hit the journal, then SIGKILL the
    // coordinator mid-grid — the exact crash window `--resume` exists for.
    let salt = RunStore::context_salt(&m, &corpus);
    let journal = dir.join(format!("ctx-{salt}")).join("journal.log");
    let deadline = Instant::now() + Duration::from_secs(300);
    loop {
        if std::fs::read_to_string(&journal).map(|t| t.contains("\ntrunk ")).unwrap_or(false) {
            break;
        }
        assert!(Instant::now() < deadline, "no trunk commit appeared in {journal:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
    serve.kill().expect("SIGKILLing the coordinator");
    serve.wait().unwrap();

    // Phase 2: restart the coordinator on the SAME address with --resume.
    // The kernel may hold the port briefly (TIME_WAIT residue of the
    // killed process's sockets), so the rebind retries; meanwhile the
    // workers' backoff loops are redialing the very same address.
    let rebind_deadline = Instant::now() + Duration::from_secs(60);
    let server = loop {
        match FabricServer::bind(addr.as_str()) {
            Ok(s) => break s,
            Err(e) => {
                assert!(Instant::now() < rebind_deadline, "could not rebind {addr}: {e:#}");
                std::thread::sleep(Duration::from_millis(500));
            }
        }
    };
    let graph = JobGraph::lower(plans.clone()).unwrap();
    let opts = FabricOptions { resume: true, ..FabricOptions::default() };
    let mut store = RunStore::open_salted(&dir, &salt).unwrap();
    let (outcome, stats) = server.run(&m, &corpus, &graph, &opts, Some(&mut store)).unwrap();
    drop(store);
    // The defector may still be mid-backoff when the sweep finishes and
    // would then burn its whole retry budget against a closed port; its
    // clean-exit contract is already pinned by the fabric test above.
    defector.kill().ok();
    defector.wait().ok();
    assert!(survivor.wait().unwrap().success(), "survivor must exit cleanly");

    assert_identical(&reference, &outcome, "resumed fabric grid");
    assert!(stats.resumed_jobs >= 1, "the restart must replay journal commits: {stats:?}");
    assert!(
        stats.resumed_jobs + stats.dispatched_jobs >= graph.jobs().len(),
        "every job is either resumed or dispatched: {stats:?}"
    );
    if stats.dispatched_jobs > 0 {
        assert!(
            stats.connections >= 1,
            "remaining work must have been served to a redialing worker: {stats:?}"
        );
    }

    // Phase 3: fully warm --resume — zero dispatches, zero snapshot bytes.
    let server = FabricServer::bind("127.0.0.1:0").unwrap();
    let mut store = RunStore::open_salted(&dir, &salt).unwrap();
    let (warm, wstats) = server.run(&m, &corpus, &graph, &opts, Some(&mut store)).unwrap();
    assert_identical(&reference, &warm, "fully warm resume");
    assert_eq!(wstats.dispatched_jobs, 0, "warm resume must dispatch nothing: {wstats:?}");
    assert_eq!(wstats.snapshots_shipped, 0, "warm resume must ship no snapshots: {wstats:?}");
    assert_eq!(wstats.snapshot_bytes_shipped, 0, "warm resume must ship zero bytes: {wstats:?}");
    assert_eq!(wstats.resumed_jobs, graph.jobs().len(), "all jobs from the journal: {wstats:?}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn chaos_drill_suite_passes_on_a_small_grid() {
    // Acceptance (deterministic fault injection, DESIGN.md §10): every
    // fault kind the faultline can inject — connection drop, torn frame,
    // stall past the heartbeat timeout, duplicated Done, and losing every
    // engine — exercised by `run_chaos` on a small shared-trunk grid.
    // Survivable faults must end bit-identical to serial; the fatal one
    // must error loudly; a hang kills the process.
    use deep_progressive::fabric::run_chaos;

    let Some(m) = manifest() else { return };
    let corpus = Corpus::generate(CorpusConfig::default());
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let spec = recipe::LadderGridSpec {
        // 1-layer base rung: the strategy list includes `copying`, which is
        // Table-2-inapplicable to a 0-layer source (the plan vet rejects it).
        rungs: &["gpt2.l1", "gpt2.l3"],
        steps: 80,
        seed: 17,
        sched,
        base: ExpandSpec::default(),
        rewarm: 0,
        taus: Some(vec![0.3]),
        strategies: Some(vec!["random".into(), "zero".into(), "copying".into()]),
        eval_every: Some(20),
        transfer: TransferRule::Fixed,
    };
    let plans = recipe::ladder_grid(&spec).unwrap();
    run_chaos(&m, &corpus, &plans, std::time::Duration::from_secs(240)).unwrap();
}

#[test]
fn store_gc_then_resume_retrains_exactly_the_collected_work() {
    // Acceptance (`repro store gc`): after a narrower sweep re-records its
    // refs, GC collects the runs only the wider grid referenced; rerunning
    // the wider grid against the collected store re-trains exactly those
    // runs (the survivor is served from cache) and ends bit-identical.
    use deep_progressive::coordinator::{RunPlan, SweepOutcome};
    use deep_progressive::exec::JobGraph;
    use deep_progressive::fabric::{FabricOptions, FabricServer};
    use deep_progressive::store::RunStore;

    let Some(m) = manifest() else { return };
    let corpus = small_corpus();
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let fixed = |name: &str, seed: u64| -> RunPlan {
        RunBuilder::fixed(name, "gpt2.l1", 80, sched).eval_every(20).seed(seed).build().unwrap()
    };
    let grid = || vec![fixed("gc-a", 1), fixed("gc-b", 2), fixed("gc-c", 3)];

    let dir = std::env::temp_dir().join(format!("dpt_gc_store_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let salt = RunStore::context_salt(&m, &corpus);
    // Through the coordinator with local engine threads: the same
    // record-refs → execute → journal path a distributed sweep takes.
    let serve = |plans: Vec<RunPlan>| {
        let graph = JobGraph::lower(plans).unwrap();
        let server = FabricServer::bind("127.0.0.1:0").unwrap();
        let mut store = RunStore::open_salted(&dir, &salt).unwrap();
        let opts =
            FabricOptions { local_workers: 2, keep_states: true, ..FabricOptions::default() };
        server.run(&m, &corpus, &graph, &opts, Some(&mut store)).unwrap()
    };

    let (full, stats) = serve(grid());
    assert_eq!(stats.dispatched_jobs, 3);
    assert_eq!(stats.local_jobs, 3);

    // A narrower sweep referencing only gc-a: fully cached, but its refs
    // line supersedes the grid's for liveness.
    let (_, sub) = serve(vec![fixed("gc-a", 1)]);
    assert_eq!(sub.dispatched_jobs, 0, "the survivor must be cache-served: {sub:?}");

    // Dry-run first: reports the two dead runs, touches nothing.
    let mut store = RunStore::open_salted(&dir, &salt).unwrap();
    let dry = store.gc(true, 1).unwrap();
    assert_eq!(dry.collected_runs.len(), 2, "{dry:?}");
    assert_eq!(dry.live_runs, 1, "{dry:?}");
    let real = store.gc(false, 1).unwrap();
    assert_eq!(real.collected_runs, dry.collected_runs, "dry-run must predict the real GC");
    assert!(real.bytes_reclaimed > 0);
    drop(store);

    // Resume the wide grid: exactly the collected runs re-train.
    let (resumed, rstats) = serve(grid());
    assert_eq!(rstats.dispatched_jobs, 2, "only the GC'd runs may re-train: {rstats:?}");
    assert_eq!(rstats.cached_jobs, 1, "the survivor must still be cache-served: {rstats:?}");

    let assert_identical = |a: &SweepOutcome, b: &SweepOutcome| {
        assert_eq!(a.results.len(), b.results.len());
        assert_eq!(a.executed_flops.to_bits(), b.executed_flops.to_bits());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.curve.name, y.curve.name);
            assert_eq!(x.curve.points, y.curve.points, "curve diverged ('{}')", x.curve.name);
            assert_eq!(x.final_val_loss.to_bits(), y.final_val_loss.to_bits());
        }
        for (x, y) in a.final_states.iter().zip(&b.final_states) {
            let (x, y) = (x.as_ref().expect("kept state"), y.as_ref().expect("kept state"));
            for (s, t) in x.params.iter().zip(&y.params) {
                assert_eq!(s.data, t.data, "final params diverged after GC + resume");
            }
        }
    };
    assert_identical(&full, &resumed);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn parallel_probe_pair_matches_serial() {
    // The §7 probe pair run as two lockstep engine-owning jobs must make the
    // same early-stop decision and derive the same τ as the serial path.
    let Some(m) = manifest() else { return };
    let corpus = small_corpus();
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let serial = {
        let engine = Engine::cpu().unwrap();
        let trainer = Trainer::new(&engine, &m, &corpus);
        recipe::probe_mixing_time(
            &trainer,
            "gpt2.l0",
            "gpt2.l3",
            160,
            1600,
            sched,
            ExpandSpec::default(),
            0.05,
        )
        .unwrap()
    };
    let par = recipe::probe_mixing_time_parallel(
        &m,
        &corpus,
        "gpt2.l0",
        "gpt2.l3",
        160,
        1600,
        sched,
        ExpandSpec::default(),
        0.05,
    )
    .unwrap();
    assert_eq!(serial, par);
}

#[test]
fn observer_hooks_fire_in_documented_order_for_arbitrary_ladders() {
    // Observer-contract property: for ANY multi-stage plan, each boundary
    // fires `on_pre_boundary`, then the PreBoundary eval, then the
    // PostBoundary eval, then `on_boundary`; every `on_layer_stats` rides
    // immediately after its eval at the same step; a boundary landing
    // exactly on the eval cadence suppresses that step's Cadence eval
    // (never a duplicate); `on_finish` fires exactly once, last.
    use deep_progressive::coordinator::{
        BoundaryEvent, EvalEvent, LadderRound, LayerStatsEvent, Observer, PreBoundaryEvent,
        RunSummary, Signal,
    };
    use std::cell::RefCell;
    use std::rc::Rc;

    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let trainer = Trainer::new(&engine, &m, &corpus);

    #[derive(Default)]
    struct Recorder {
        events: Vec<(String, usize)>,
    }
    impl Observer for Recorder {
        fn on_eval(&mut self, ev: &EvalEvent<'_>) {
            self.events.push((format!("eval:{:?}", ev.kind), ev.point.step));
        }
        fn on_layer_stats(&mut self, ev: &LayerStatsEvent<'_>) {
            self.events.push(("layer_stats".into(), ev.step));
        }
        fn on_pre_boundary(&mut self, ev: &PreBoundaryEvent<'_>) -> Signal {
            self.events.push(("pre_boundary".into(), ev.step));
            Signal::Continue
        }
        fn on_boundary(&mut self, ev: &BoundaryEvent<'_>) {
            self.events.push(("boundary".into(), ev.step));
        }
        fn on_finish(&mut self, _s: &RunSummary<'_>) {
            self.events.push(("finish".into(), usize::MAX));
        }
    }

    let rungs = ["gpt2.l0", "gpt2.l1", "gpt2.l3"];
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    deep_progressive::util::proptest::proptest(6, |g| {
        let total = 30;
        let eval_every = *g.choose(&[1usize, 2, 3, 5]);
        let n_rounds = g.usize(1..3);
        let mut rounds = Vec::new();
        for r in 0..n_rounds {
            // Disjoint windows keep boundaries strictly increasing; about
            // half the cases snap a boundary up onto the eval cadence to
            // exercise the boundary-hits-cadence edge.
            let (lo, hi) = if r == 0 { (4, 12) } else { (18, 26) };
            let mut at = g.usize(lo..hi);
            if g.bool() {
                at = at.div_ceil(eval_every) * eval_every;
            }
            rounds.push(LadderRound::new(rungs[r + 1], at, ExpandSpec::default()));
        }
        let plan = RunBuilder::ladder("obs-order", rungs[0], &rounds, total, sched)
            .eval_every(eval_every)
            .diag(g.bool())
            .build()
            .unwrap();
        let boundaries: Vec<usize> =
            (1..=plan.n_boundaries()).filter_map(|d| plan.boundary_at(d)).collect();

        let rec = Rc::new(RefCell::new(Recorder::default()));
        let mut d = RunDriver::new(trainer, plan).unwrap();
        d.attach(Box::new(rec.clone()));
        d.run_to_end().unwrap();
        let _ = d.finish();
        let events = rec.borrow().events.clone();

        assert_eq!(events.last().map(|(k, _)| k.as_str()), Some("finish"));
        assert_eq!(events.iter().filter(|(k, _)| k == "finish").count(), 1);
        for (i, (k, step)) in events.iter().enumerate() {
            if k == "layer_stats" {
                let (pk, ps) = &events[i - 1];
                assert!(pk.starts_with("eval:"), "layer_stats rode after '{pk}', not an eval");
                assert_eq!(ps, step, "layer_stats step differs from its eval's");
            }
        }
        let spans: Vec<&(String, usize)> =
            events.iter().filter(|(k, _)| k != "layer_stats").collect();
        for &b in &boundaries {
            let i = spans
                .iter()
                .position(|(k, s)| k == "pre_boundary" && *s == b)
                .unwrap_or_else(|| panic!("no pre_boundary at step {b}: {events:?}"));
            assert_eq!((spans[i + 1].0.as_str(), spans[i + 1].1), ("eval:PreBoundary", b));
            assert_eq!((spans[i + 2].0.as_str(), spans[i + 2].1), ("eval:PostBoundary", b));
            assert_eq!((spans[i + 3].0.as_str(), spans[i + 3].1), ("boundary", b));
            assert!(
                !events.iter().any(|(k, s)| k == "eval:Cadence" && *s == b),
                "cadence eval duplicated at boundary step {b}: {events:?}"
            );
        }
        let fired: Vec<usize> =
            events.iter().filter(|(k, _)| k == "boundary").map(|(_, s)| *s).collect();
        assert_eq!(fired, boundaries, "boundaries fired out of order");
    });
}

#[test]
fn diagnostics_leave_curves_byte_equal_and_replay_bit_identical() {
    // The diagnostics hard contract: probe dispatches never perturb the
    // training trajectory (curves byte-equal diag on/off), the recorded
    // per-layer rows are bit-identical at any worker count, and a warm
    // store replays them without recomputation.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let trainer = Trainer::new(&engine, &m, &corpus);
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let spec = ExpandSpec::default();
    let plan = |diag: bool| {
        RunBuilder::progressive("diagx", "gpt2.l0", "gpt2.l3", 40, 120, sched, spec)
            .diag(diag)
            .build()
            .unwrap()
    };

    let off = run_plan(trainer, plan(false));
    let on = run_plan(trainer, plan(true));
    assert_eq!(off.curve.to_csv(), on.curve.to_csv(), "diagnostics perturbed the curve");
    assert_eq!(off.final_val_loss.to_bits(), on.final_val_loss.to_bits());
    assert_eq!(off.ledger.total.to_bits(), on.ledger.total.to_bits());
    assert!(off.layer_stats.is_empty(), "diag-off run recorded layer stats");
    assert!(
        !on.layer_stats.is_empty(),
        "diag run recorded no layer stats (probe artifacts missing?)"
    );

    // Any worker count reproduces the rows byte-for-byte (CSV form).
    let par = {
        let mut sweep = Sweep::new(trainer);
        sweep.add(plan(true));
        sweep.run_parallel(2).unwrap()
    };
    assert_eq!(
        deep_progressive::diag::layer_stats_csv(&on.layer_stats),
        deep_progressive::diag::layer_stats_csv(&par.results[0].layer_stats),
        "layer stats diverged under parallel execution"
    );

    // Warm store: the rerun serves the run from cache, rows included.
    let dir = std::env::temp_dir().join(format!("diag-replay-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let stored = || {
        let mut sweep = Sweep::new(trainer);
        sweep.store(&dir).unwrap();
        sweep.add(plan(true));
        sweep.run_parallel(1).unwrap()
    };
    let cold = stored();
    let warm = stored();
    assert_eq!(
        cold.results[0].layer_stats,
        warm.results[0].layer_stats,
        "warm store replayed different layer stats"
    );
    assert_eq!(cold.results[0].layer_stats, on.layer_stats);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn planted_violation_grids_are_rejected_before_any_store_write_or_dispatch() {
    // Acceptance (plan vet, DESIGN.md §13): a grid with a planted contract
    // violation — τ = 0.95 puts the expansion boundary inside the WSD decay
    // phase (Takeaway 6) — must be refused by `repro sweep`, `repro ladder`,
    // and `repro serve` with a nonzero exit, a vet error naming the lint,
    // ZERO store writes (the store directory is never even created), and
    // zero dispatches (`serve` never binds its socket). The same grid with
    // a stable-phase τ sails through `repro vet`.
    use std::process::{Command, Stdio};
    let Some(_m) = manifest() else { return };
    let artifacts_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let scratch = std::env::temp_dir().join(format!("dpt_vet_gate_{}", std::process::id()));
    std::fs::remove_dir_all(&scratch).ok();
    std::fs::create_dir_all(&scratch).unwrap();

    let run = |argv: &[&str], store: &std::path::Path| -> (bool, String, String) {
        let out = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(argv)
            .arg("--artifacts")
            .arg(&artifacts_root)
            .arg("--store-dir")
            .arg(store)
            .arg("--out")
            .arg(scratch.join("csv"))
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .output()
            .expect("spawning repro");
        (
            out.status.success(),
            String::from_utf8_lossy(&out.stdout).into_owned(),
            String::from_utf8_lossy(&out.stderr).into_owned(),
        )
    };

    for (label, argv) in [
        ("sweep", vec!["sweep", "gpt2.l0", "gpt2.l2", "--taus", "0.95", "--steps", "240"]),
        ("ladder", vec!["ladder", "gpt2.l0", "gpt2.l2", "--taus", "0.95", "--steps", "240"]),
        (
            "serve",
            vec![
                "serve", "gpt2.l0", "gpt2.l2", "--taus", "0.95", "--steps", "240",
                "--listen", "127.0.0.1:0", "--workers", "1",
            ],
        ),
    ] {
        let store = scratch.join(format!("store-{label}"));
        let (ok, stdout, stderr) = run(&argv, &store);
        assert!(!ok, "{label}: a planted-violation grid must exit nonzero\n{stdout}{stderr}");
        assert!(
            stderr.contains("plan vet found") && stderr.contains("boundary-in-decay"),
            "{label}: rejection must come from the vet gate and name the lint:\n{stderr}"
        );
        assert!(
            !store.exists(),
            "{label}: the store must never be created for an unvetted grid"
        );
        if label == "serve" {
            assert!(
                !stdout.contains("listening"),
                "serve must reject the grid before binding its socket:\n{stdout}"
            );
        }
    }

    // `repro vet` itself: the planted grid fails loudly with a report…
    let report = scratch.join("vet-report.json");
    let bad = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["vet", "gpt2.l0", "gpt2.l2", "--taus", "0.95", "--steps", "240"])
        .arg("--artifacts")
        .arg(&artifacts_root)
        .arg("--report")
        .arg(&report)
        .output()
        .expect("spawning repro vet");
    assert!(!bad.status.success(), "vet must exit nonzero on the planted grid");
    let text = String::from_utf8_lossy(&bad.stdout);
    assert!(text.contains("boundary-in-decay") && text.contains("vet: FAIL"), "{text}");
    let json = std::fs::read_to_string(&report).expect("vet --report file");
    assert!(json.contains("boundary-in-decay"), "report missing the finding: {json}");

    // …and the stable-phase version of the very same grid passes clean.
    let good = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["vet", "gpt2.l0", "gpt2.l2", "--taus", "0.5", "--steps", "240"])
        .arg("--artifacts")
        .arg(&artifacts_root)
        .output()
        .expect("spawning repro vet");
    let text = String::from_utf8_lossy(&good.stdout);
    assert!(good.status.success(), "a clean grid must pass vet: {text}");
    assert!(text.contains("vet: PASS"), "{text}");

    std::fs::remove_dir_all(&scratch).ok();
}
