//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts` to have run (they are skipped with a clear
//! message otherwise, so `cargo test` stays green on a fresh checkout).

use deep_progressive::coordinator::{RunSpec, Trainer};
use deep_progressive::data::{Corpus, CorpusConfig};
use deep_progressive::expansion::{expand, CopyOrder, ExpandSpec, OsPolicy, Strategy};
use deep_progressive::metrics::mixing_point;
use deep_progressive::runtime::{Engine, IntTensor, Manifest, ModelState};
use deep_progressive::schedule::Schedule;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&root) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

fn small_corpus() -> Corpus {
    Corpus::generate(CorpusConfig {
        vocab: 512,
        train_tokens: 200_000,
        val_tokens: 20_000,
        ..Default::default()
    })
}

#[test]
fn train_step_learns() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let entry = m.get("gpt2.l1").unwrap();
    let mut state = ModelState::init(entry, 0);
    let mut batcher = deep_progressive::data::Batcher::new(&corpus.train, entry.model.seq_len, 3);
    let b = entry.model.batch;
    let s = entry.model.seq_len;
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..80 {
        let (x, y) = batcher.next_batch(b);
        let x = IntTensor::from_vec(&[b, s], x).unwrap();
        let y = IntTensor::from_vec(&[b, s], y).unwrap();
        last = engine
            .train_step(entry, &m.root, &mut state, &x, &y, 0.01, None)
            .unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last < first - 0.05, "loss did not decrease: {first} -> {last}");
}

#[test]
fn chunk_matches_single_steps() {
    // The fused K-step artifact must produce the same final state as K
    // single-step dispatches on the same data (the hot path is a pure
    // batching optimization, not a semantic change).
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let entry = m.get("gpt2.l0").unwrap();
    let b = entry.model.batch;
    let s = entry.model.seq_len;
    let k = entry.chunk;

    let mut batcher = deep_progressive::data::Batcher::new(&corpus.train, s, 5);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut batches = Vec::new();
    for _ in 0..k {
        let (x, y) = batcher.next_batch(b);
        xs.extend_from_slice(&x);
        ys.extend_from_slice(&y);
        batches.push((x, y));
    }
    let lrs: Vec<f32> = (0..k).map(|i| 0.005 + 0.001 * i as f32).collect();

    let mut st_chunk = ModelState::init(entry, 9);
    let xs_t = IntTensor::from_vec(&[k, b, s], xs).unwrap();
    let ys_t = IntTensor::from_vec(&[k, b, s], ys).unwrap();
    let losses = engine
        .train_chunk(entry, &m.root, &mut st_chunk, &xs_t, &ys_t, &lrs, None)
        .unwrap();
    assert_eq!(losses.len(), k);

    let mut st_single = ModelState::init(entry, 9);
    let mut single_losses = Vec::new();
    for (i, (x, y)) in batches.iter().enumerate() {
        let x = IntTensor::from_vec(&[b, s], x.clone()).unwrap();
        let y = IntTensor::from_vec(&[b, s], y.clone()).unwrap();
        single_losses.push(
            engine
                .train_step(entry, &m.root, &mut st_single, &x, &y, lrs[i], None)
                .unwrap(),
        );
    }
    for (a, b_) in losses.iter().zip(&single_losses) {
        assert!((a - b_).abs() < 1e-4, "chunk loss {a} vs single {b_}");
    }
    for (a, b_) in st_chunk.params.iter().zip(&st_single.params) {
        let maxdiff = a
            .data
            .iter()
            .zip(&b_.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(maxdiff < 1e-4, "params diverged: {maxdiff}");
    }
}

#[test]
fn zero_and_copying_zero_l_are_function_preserving() {
    // Takeaway 2 / §A.2: zero and copying_zeroL expansions must leave the
    // validation loss exactly unchanged (block outputs vanish).
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let src = m.get("gpt2.l1").unwrap();
    let dst = m.get("gpt2.l3").unwrap();
    let state = ModelState::init(src, 4);
    let b = src.model.batch;
    let s = src.model.seq_len;
    let mut batcher = deep_progressive::data::Batcher::new(&corpus.val, s, 1);
    let (x, y) = batcher.next_batch(b);
    let x = IntTensor::from_vec(&[b, s], x).unwrap();
    let y = IntTensor::from_vec(&[b, s], y).unwrap();
    let base = engine.eval_step(src, &m.root, &state, &x, &y, None).unwrap();

    for strategy in [Strategy::Zero, Strategy::CopyingZeroL] {
        let spec = ExpandSpec { strategy, ..Default::default() };
        let big = expand(src, dst, &state, &spec).unwrap();
        let loss = engine.eval_step(dst, &m.root, &big, &x, &y, None).unwrap();
        assert!(
            (loss - base).abs() < 5e-4,
            "{strategy:?} not function-preserving: {base} -> {loss}"
        );
    }

    // Copying (no zeroing) must NOT be function-preserving in general.
    let spec = ExpandSpec { strategy: Strategy::Copying(CopyOrder::Stack), ..Default::default() };
    let big = expand(src, dst, &state, &spec).unwrap();
    let loss = engine.eval_step(dst, &m.root, &big, &x, &y, None).unwrap();
    assert!((loss - base).abs() > 1e-3, "copying unexpectedly preserved the function");
}

#[test]
fn expansion_preserves_old_layer_bytes() {
    let Some(m) = manifest() else { return };
    let src = m.get("gpt2.l2").unwrap();
    let dst = m.get("gpt2.l6").unwrap();
    let state = ModelState::init(src, 11);
    let spec = ExpandSpec { strategy: Strategy::Random, os_policy: OsPolicy::Inherit, ..Default::default() };
    let big = expand(src, dst, &state, &spec).unwrap();
    // Old layers 0..2 and non-layer params must be bit-identical.
    for (i, pspec) in dst.params.iter().enumerate() {
        let keep = match pspec.layer_index() {
            None => true,
            Some(j) => j < 2,
        };
        if keep {
            let src_t = state.param(src, &pspec.name).unwrap();
            assert_eq!(src_t.data, big.params[i].data, "{} changed", pspec.name);
        }
    }
}

#[test]
fn progressive_run_end_to_end_mixes() {
    // Miniature Fig-3: zero-layer -> 3-layer progressive under constant LR
    // mixes with the fixed-size 3-layer run.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let corpus = small_corpus();
    let trainer = Trainer::new(&engine, &m, &corpus);
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    let total = 240;

    let fixed = trainer.run(&RunSpec::fixed("fixed-l3", "gpt2.l3", total, sched)).unwrap();
    let prog = trainer
        .run(&RunSpec::progressive(
            "prog-l0-l3",
            "gpt2.l0",
            "gpt2.l3",
            48,
            total,
            sched,
            ExpandSpec::default(),
        ))
        .unwrap();

    assert_eq!(prog.boundaries.len(), 1);
    // The progressive run costs less compute...
    assert!(prog.ledger.total < fixed.ledger.total * 0.95);
    // ...and its loss approaches the fixed run's (generous tolerance at this
    // tiny scale: within 5% by the end or formally mixed).
    let gap = (prog.final_val_loss - fixed.final_val_loss) / fixed.final_val_loss;
    let mixed = mixing_point(&prog.curve, &fixed.curve, 0.05, 2).is_some();
    assert!(mixed || gap < 0.05, "gap {gap}, mixed {mixed}");
}
