//! Property-based tests on coordinator invariants (DESIGN.md §5): schedule
//! algebra, batcher coverage, expansion remapping, FLOP accounting, mixing
//! detector monotonicity, JSON round-trips. No PJRT needed — these run on
//! any checkout.

use deep_progressive::coordinator::{LadderRound, RunBuilder, RunPlan, RunResult};
use deep_progressive::data::{Batcher, Corpus, CorpusConfig};
use deep_progressive::exec::{GroupSpec, JobGraph, JobKind};
use deep_progressive::flops::FlopLedger;
use deep_progressive::expansion::{applicable, expand, CopyOrder, ExpandSpec, Insertion, OsPolicy, Strategy};
use deep_progressive::metrics::{mixing_point, Curve, CurvePoint};
use deep_progressive::runtime::{Manifest, ModelState};
use deep_progressive::schedule::Schedule;
use deep_progressive::util::json::Json;
use deep_progressive::util::proptest::proptest;

// ---------------------------------------------------------------- schedules

#[test]
fn prop_schedules_are_bounded_and_end_low() {
    proptest(200, |g| {
        let peak = g.f32(1e-4, 0.1);
        let total = g.usize(50..5000);
        let decay_frac = g.f32(0.05, 0.5);
        let sched = *g.choose(&[
            Schedule::Wsd { peak, warmup_frac: 0.02, decay_frac },
            Schedule::cosine(peak),
            Schedule::Constant { peak, warmup_frac: 0.02 },
            Schedule::Linear { peak, warmup_frac: 0.02 },
        ]);
        for t in [0, total / 3, total / 2, total - 1] {
            let lr = sched.lr(t, total);
            assert!(
                (0.0..=peak * (1.0 + 1e-5)).contains(&lr),
                "lr {lr} out of [0, {peak}] at {t}/{total}"
            );
        }
        // All decaying schedules end below 10% of peak.
        if !matches!(sched, Schedule::Constant { .. }) {
            assert!(sched.lr(total - 1, total) <= peak * 0.1 + 1e-7);
        }
    });
}

#[test]
fn prop_wsd_stable_phase_is_constant() {
    proptest(100, |g| {
        let total = g.usize(100..3000);
        let decay = g.f32(0.05, 0.4);
        let sched = Schedule::Wsd { peak: 0.01, warmup_frac: 0.02, decay_frac: decay };
        let warm_end = (total as f32 * 0.02).ceil() as usize + 1;
        let stable_end = sched.stable_end(total);
        if warm_end + 1 < stable_end {
            let a = sched.lr(warm_end + 1, total);
            let b = sched.lr(stable_end - 1, total);
            assert!((a - b).abs() < 1e-7, "stable phase not constant: {a} vs {b}");
        }
    });
}

#[test]
fn prop_lr_sum_additive() {
    proptest(100, |g| {
        let total = g.usize(10..2000);
        let mid = g.usize(1..total);
        let sched = Schedule::wsd(0.01);
        let whole = sched.lr_sum(0, total, total);
        let split = sched.lr_sum(0, mid, total) + sched.lr_sum(mid, total, total);
        assert!((whole - split).abs() < 1e-9);
    });
}

// ------------------------------------------------------------------ builder

#[test]
fn prop_builder_accepts_iff_boundaries_strictly_increasing_inside_horizon() {
    proptest(200, |g| {
        let total = g.usize(10..2000);
        let n_extra = g.usize(0..4);
        let mut b = RunBuilder::new("p")
            .start("cfg0")
            .total_steps(total)
            .schedule(Schedule::Constant { peak: 0.01, warmup_frac: 0.02 });
        let mut steps = Vec::new();
        for i in 0..n_extra {
            let s = g.usize(0..total * 2);
            steps.push(s);
            b = b.then_expand_at(s, format!("cfg{}", i + 1), ExpandSpec::default());
        }
        let valid = steps.windows(2).all(|w| w[1] > w[0])
            && steps.first().map(|&s| s > 0).unwrap_or(true)
            && steps.last().map(|&s| s < total).unwrap_or(true);
        let built = b.build();
        assert_eq!(built.is_ok(), valid, "steps {steps:?} total {total}: {built:?}");
        if let Ok(plan) = built {
            assert_eq!(plan.stages().len(), n_extra + 1);
            assert!(plan.eval_every() >= 1);
            // The plan is immutable and self-consistent: first_boundary is
            // either the first declared boundary or the horizon.
            assert_eq!(plan.first_boundary(), steps.first().copied().unwrap_or(total));
        }
    });
}

#[test]
fn prop_rewarm_ladders_keep_lr_bounded_and_discontinuity_free() {
    // Arbitrary multi-round re-warm ladders: `lr_at` never exceeds the
    // schedule peak, each ramp climbs monotonically, its last step re-joins
    // the base schedule (no discontinuity at the ramp edge), and every step
    // outside a ramp IS the untouched base schedule.
    proptest(200, |g| {
        let total = g.usize(100..2000);
        let peak = g.f32(1e-4, 0.1);
        let decay_frac = g.f32(0.05, 0.4);
        let sched = *g.choose(&[
            Schedule::Wsd { peak, warmup_frac: 0.02, decay_frac },
            Schedule::Constant { peak, warmup_frac: 0.02 },
        ]);
        let n_rounds = g.usize(1..4);
        let mut bounds = Vec::new();
        let mut lo = 1usize;
        for i in 0..n_rounds {
            // Leave one-step slack per remaining round so the sequence can
            // stay strictly increasing inside the horizon.
            let slack = n_rounds - 1 - i;
            if lo >= total - slack {
                break;
            }
            let b = g.usize(lo..total - slack);
            bounds.push(b);
            lo = b + 1;
        }
        let mut rounds = Vec::new();
        let mut rewarms = Vec::new();
        for (i, &b) in bounds.iter().enumerate() {
            let stage_end = bounds.get(i + 1).copied().unwrap_or(total);
            // The builder rejects ramps past the stage end; stay inside.
            let rewarm = g.usize(0..stage_end - b + 1);
            rewarms.push(rewarm);
            rounds.push(
                LadderRound::new(format!("l{}", i + 1), b, ExpandSpec::default())
                    .rewarm(rewarm),
            );
        }
        let plan = RunBuilder::ladder("prop-rewarm", "l0", &rounds, total, sched)
            .build()
            .expect("in-bounds re-warm ladders must build");

        let in_ramp =
            |t: usize| bounds.iter().zip(&rewarms).any(|(&b, &r)| t >= b && t < b + r);
        for t in (0..total).step_by((total / 257).max(1)).chain([total - 1]) {
            let lr = plan.lr_at(t);
            assert!(
                (0.0..=peak * (1.0 + 1e-5)).contains(&lr),
                "lr {lr} out of [0, {peak}] at {t}/{total}"
            );
            if !in_ramp(t) {
                // Outside every ramp the plan is exactly the base schedule.
                assert_eq!(lr, sched.lr(t, total), "off-ramp divergence at {t}");
            }
        }
        for (&b, &r) in bounds.iter().zip(&rewarms) {
            if r == 0 {
                continue;
            }
            let mut prev_frac = 0.0f32;
            for k in 0..r {
                let base = sched.lr(b + k, total);
                let lr = plan.lr_at(b + k);
                let want = base * (k + 1) as f32 / r as f32;
                assert!(
                    (lr - want).abs() <= want.abs() * 1e-5 + 1e-12,
                    "ramp step {k}/{r} at {}: lr {lr} != {want}",
                    b + k
                );
                if base > 0.0 {
                    let frac = lr / base;
                    assert!(frac >= prev_frac - 1e-6, "ramp not monotone at {}", b + k);
                    prev_frac = frac;
                }
            }
            // The final ramp step is the base schedule again: re-entry is
            // continuous, with no jump where the ramp hands back to base.
            let rejoin = plan.lr_at(b + r - 1);
            let base = sched.lr(b + r - 1, total);
            assert!(
                (rejoin - base).abs() <= base.abs() * 1e-5 + 1e-12,
                "ramp at {b} re-joins {rejoin}, base is {base}"
            );
            if b + r < total {
                assert_eq!(plan.lr_at(b + r), sched.lr(b + r, total));
            }
        }
    });
}

// ---------------------------------------------------------------- job graph

#[test]
fn prop_job_graph_lowering_invariants() {
    // Arbitrary grids: a few "prefix classes" (shared stage-0 config, seed,
    // horizon), each plan either fixed or progressive with one of a few τs.
    // Plans share a trunk iff prefix AND first boundary coincide.
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    proptest(300, |g| {
        let n_plans = g.usize(1..12);
        let mut plans = Vec::with_capacity(n_plans);
        for i in 0..n_plans {
            let class = g.usize(0..3);
            let total = 100 + class * 60;
            let mut b = RunBuilder::new(format!("p{i}"))
                .start(format!("src{class}"))
                .total_steps(total)
                .schedule(sched)
                .eval_every(10)
                .seed(class as u64);
            if g.bool() {
                let tau = 20 + g.usize(0..3) * 10;
                b = b.then_expand_at(tau, format!("dst{class}"), ExpandSpec::default());
            }
            plans.push(b.build().unwrap());
        }
        let graph = JobGraph::lower(plans.clone()).unwrap();

        // 1. Every plan chains into exactly one result-producing job.
        let mut owners = vec![0usize; n_plans];
        for j in graph.jobs() {
            if let Some(idx) = j.kind.result_plan() {
                owners[idx] += 1;
            }
        }
        assert!(owners.iter().all(|&c| c == 1), "result-job ownership: {owners:?}");

        // 2. Job ids are their positions and dependencies always precede
        //    their job — the job list is its own topological order.
        for (pos, j) in graph.jobs().iter().enumerate() {
            assert_eq!(j.id, pos);
            for &d in &j.deps {
                assert!(d < j.id, "dep {d} does not precede job {}", j.id);
            }
        }

        // 3. Group coherence: members share the key, keys are unique, and
        //    the groups partition the plan set.
        let mut seen_keys = std::collections::HashSet::new();
        let mut all_idxs = Vec::new();
        for gr in graph.groups() {
            assert!(seen_keys.insert(gr.key.clone()), "duplicate group key {}", gr.key);
            for &i in &gr.plan_idxs {
                assert_eq!(JobGraph::group_key(&plans[i]), gr.key);
                all_idxs.push(i);
            }
            let fork = plans[gr.plan_idxs[0]].first_boundary();
            if gr.plan_idxs.len() > 1 && fork > 0 {
                // 4. Shared group: exactly one trunk at the common fork step;
                //    every tail chains to it (and only to it). These plans
                //    have at most one boundary, so no nesting appears.
                let t = gr.trunk.expect("shared group must have a trunk");
                let JobKind::Trunk { plan_idx, fork_step, depth, parent } = graph.jobs()[t].kind
                else {
                    panic!("group trunk {t} is not a trunk job");
                };
                assert!(gr.plan_idxs.contains(&plan_idx));
                assert_eq!(fork_step, fork);
                assert_eq!(depth, 1, "single-boundary plans must lower to depth-1 trunks");
                assert!(parent.is_none());
                assert!(gr.children.is_empty());
                assert_eq!(gr.direct, gr.plan_idxs);
                for &i in &gr.plan_idxs {
                    assert_eq!(plans[i].first_boundary(), fork, "fork step mismatch in group");
                }
                let tails: Vec<_> = graph
                    .jobs()
                    .iter()
                    .filter(|j| matches!(j.kind, JobKind::Tail { trunk, .. } if trunk == t))
                    .collect();
                assert_eq!(tails.len(), gr.plan_idxs.len(), "one tail per variant");
                for tail in tails {
                    assert_eq!(tail.deps, vec![t]);
                    let JobKind::Tail { plan_idx, .. } = tail.kind else { unreachable!() };
                    assert!(gr.plan_idxs.contains(&plan_idx));
                }
                assert_eq!(graph.dependents(t).len(), gr.plan_idxs.len());
            } else {
                assert!(gr.trunk.is_none(), "singleton group must not grow a trunk");
            }
        }
        all_idxs.sort_unstable();
        assert_eq!(all_idxs, (0..n_plans).collect::<Vec<_>>(), "groups must partition the plans");

        // 5. Shared trunks appear exactly once: one trunk job per shared
        //    group, none anywhere else.
        let trunk_jobs =
            graph.jobs().iter().filter(|j| matches!(j.kind, JobKind::Trunk { .. })).count();
        let shared_groups = graph.groups().iter().filter(|gr| gr.trunk.is_some()).count();
        assert_eq!(trunk_jobs, shared_groups);
    });
}

#[test]
fn prop_ladder_lowering_nests_and_deduplicates() {
    // Arbitrary multi-round (ladder) grids: plans with 0..=3 expansion
    // rounds drawn from small per-round vocabularies (boundary step, spec
    // seed, re-warm), so multi-round prefixes collide often. Invariants:
    // result-job ownership, topological order, recursive node coherence
    // (direct + children partition each node; child trunks chain to their
    // parent with strictly increasing fork steps; members agree on the
    // node's share key), and the nested FLOP dedup — `assemble` must charge
    // every rung segment exactly once under a synthetic per-config cost
    // model, however the prefixes nest.
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };

    fn cost_upto(plan: &RunPlan, upto: usize) -> f64 {
        let stages = plan.stages();
        let mut c = 0.0;
        for (i, st) in stages.iter().enumerate() {
            let start = st.from_step;
            let end = stages
                .get(i + 1)
                .map(|n| n.from_step)
                .unwrap_or(plan.total_steps())
                .min(upto);
            if end > start {
                let w: f64 = st.cfg_id.bytes().map(|b| b as f64).sum::<f64>() + 1.0;
                c += (end - start) as f64 * w;
            }
        }
        c
    }

    fn check_node(
        graph: &JobGraph,
        plans: &[RunPlan],
        node: &GroupSpec,
        parent: Option<(usize, usize)>, // (parent trunk job, parent fork step)
    ) {
        let mut members: Vec<usize> = node.direct.clone();
        for c in &node.children {
            members.extend(c.plan_idxs.iter().copied());
        }
        members.sort_unstable();
        let mut declared = node.plan_idxs.clone();
        declared.sort_unstable();
        assert_eq!(members, declared, "direct + children must partition the node");
        match node.trunk {
            None => {
                assert!(node.children.is_empty(), "trunkless nodes cannot nest");
                assert!(parent.is_none());
            }
            Some(t) => {
                let JobKind::Trunk { plan_idx, fork_step, depth, parent: tparent } =
                    graph.jobs()[t].kind
                else {
                    panic!("node trunk {t} is not a trunk job");
                };
                assert!(node.plan_idxs.contains(&plan_idx));
                assert_eq!(tparent, parent.map(|(p, _)| p), "child trunks chain to their parent");
                if let Some((_, pfork)) = parent {
                    assert!(fork_step > pfork, "fork steps must increase with depth");
                }
                for &i in &node.plan_idxs {
                    if plans[i].n_boundaries() >= depth {
                        assert_eq!(
                            plans[i].share_key_upto(depth).as_deref(),
                            Some(node.key.as_str()),
                            "member {i} does not share the node key at depth {depth}"
                        );
                        assert_eq!(plans[i].boundary_at(depth), Some(fork_step));
                    } else {
                        // Identical boundary-less plans group at the horizon.
                        assert_eq!(plans[i].total_steps(), fork_step);
                    }
                }
                for c in &node.children {
                    assert!(c.plan_idxs.len() >= 2, "child nodes must actually share");
                    check_node(graph, plans, c, Some((t, fork_step)));
                }
            }
        }
    }

    proptest(300, |g| {
        let n_plans = g.usize(1..10);
        let mut plans = Vec::with_capacity(n_plans);
        for i in 0..n_plans {
            let class = g.usize(0..2);
            let total = 200 + class * 100;
            let mut b = RunBuilder::new(format!("p{i}"))
                .start(format!("src{class}"))
                .total_steps(total)
                .schedule(sched)
                .eval_every(10)
                .seed(class as u64);
            let n_rounds = g.usize(0..4);
            let tau_opts = [[20usize, 30], [50, 60], [80, 90]];
            for r in 0..n_rounds {
                let tau = tau_opts[r][g.usize(0..2)];
                let rewarm = [0usize, 5][g.usize(0..2)];
                let spec = ExpandSpec { seed: [7u64, 9][g.usize(0..2)], ..Default::default() };
                b = b.then_expand_rewarm_at(tau, format!("dst{r}"), spec, rewarm);
            }
            plans.push(b.build().unwrap());
        }
        let graph = JobGraph::lower(plans.clone()).unwrap();

        let mut owners = vec![0usize; n_plans];
        for j in graph.jobs() {
            if let Some(idx) = j.kind.result_plan() {
                owners[idx] += 1;
            }
            for &d in &j.deps {
                assert!(d < j.id, "dep {d} does not precede job {}", j.id);
            }
        }
        assert!(owners.iter().all(|&c| c == 1), "result-job ownership: {owners:?}");
        for gr in graph.groups() {
            check_node(&graph, &plans, gr, None);
        }

        // FLOP dedup: assemble's tree walk must charge exactly the per-job
        // segments (trunks: own rung only; tails: post-fork only).
        let mut trunk_costs = std::collections::HashMap::new();
        let mut expect = 0.0f64;
        for j in graph.jobs() {
            match j.kind {
                JobKind::Trunk { plan_idx, fork_step, parent, .. } => {
                    let own = cost_upto(&plans[plan_idx], fork_step);
                    trunk_costs.insert(j.id, own);
                    let parent_cost = parent.map(|p| trunk_costs[&p]).unwrap_or(0.0);
                    expect += own - parent_cost;
                }
                JobKind::Tail { plan_idx, trunk } => {
                    expect += cost_upto(&plans[plan_idx], plans[plan_idx].total_steps())
                        - trunk_costs[&trunk];
                }
                JobKind::Standalone { plan_idx } => {
                    expect += cost_upto(&plans[plan_idx], plans[plan_idx].total_steps());
                }
            }
        }
        let per_plan: Vec<_> = plans
            .iter()
            .map(|p| {
                let total = cost_upto(p, p.total_steps());
                Some((
                    RunResult {
                        curve: Curve::new(p.name()),
                        ledger: FlopLedger { total, tokens: 0, stages: Vec::new() },
                        boundaries: Vec::new(),
                        final_val_loss: 0.0,
                        layer_stats: Vec::new(),
                    },
                    None,
                ))
            })
            .collect();
        let represented: f64 = plans.iter().map(|p| cost_upto(p, p.total_steps())).sum();
        let out = graph.assemble(per_plan, |j| trunk_costs.get(&j).copied()).unwrap();
        let scale = represented.max(1.0);
        assert!(
            (out.executed_flops - expect).abs() / scale < 1e-12,
            "assemble executed {} vs per-job segments {expect}",
            out.executed_flops
        );
        assert!(
            (out.executed_flops + out.shared_flops - represented).abs() / scale < 1e-12,
            "executed {} + shared {} must equal represented {represented}",
            out.executed_flops,
            out.shared_flops
        );
    });
}

#[test]
fn prop_stable_end_matches_job_graph_fork_step_up_to_1e8() {
    // Regression (f32 truncation): `stable_end` used to compute the decay
    // boundary in f32, which loses integer precision past 2^24 — a plan
    // built with τ = stable_end then forked at a step the schedule itself
    // disagreed with. The f64 path must stay within half a step of the
    // exact product for horizons up to 10^8, and the JobGraph fork step of
    // plans expanding at stable_end must equal it exactly.
    proptest(200, |g| {
        let total = g.usize(100..100_000_000);
        let df = *g.choose(&[0.05f32, 0.1, 0.125, 0.2, 0.25, 0.4, 0.5]);
        let sched = Schedule::Wsd { peak: 0.01, warmup_frac: 0.02, decay_frac: df };
        let tau = sched.stable_end(total);
        assert!(tau >= 1 && tau < total, "stable_end {tau} outside (0, {total})");
        let exact = (1.0 - f64::from(df)) * total as f64;
        assert!(
            (tau as f64 - exact).abs() <= 0.5 + 1e-6,
            "stable_end {tau} drifted from exact {exact} (total {total}, df {df})"
        );
        let mk = |name: &str| {
            RunBuilder::progressive(name, "s", "l", tau, total, sched, ExpandSpec::default())
                .build()
                .unwrap()
        };
        let graph = JobGraph::lower(vec![mk("a"), mk("b")]).unwrap();
        let fork = graph
            .jobs()
            .iter()
            .find_map(|j| match j.kind {
                JobKind::Trunk { fork_step, .. } => Some(fork_step),
                _ => None,
            })
            .expect("two plans expanding at the same τ must share a trunk");
        assert_eq!(fork, tau, "job-graph fork step disagrees with stable_end");
    });
}

// ------------------------------------------------------------- plan digests

#[test]
fn prop_plan_digest_is_content_addressed() {
    // The run-store key (DESIGN.md §7): blind to the run name, sensitive to
    // every execution-relevant field; the trunk digest tracks the sweep's
    // sharing rule (group_key) exactly.
    let sched = Schedule::Constant { peak: 0.01, warmup_frac: 0.02 };
    proptest(200, |g| {
        let total = g.usize(50..5000);
        let tau = g.usize(1..total);
        let seed = g.usize(0..4) as u64;
        let mk = |name: &str, seed: u64, tau: usize| {
            RunBuilder::progressive(name, "s", "l", tau, total, sched, ExpandSpec::default())
                .seed(seed)
                .build()
                .unwrap()
        };
        let a = mk("a", seed, tau);
        let b = mk("b", seed, tau);
        assert_eq!(a.digest(), b.digest(), "digest must ignore the run name");
        assert_eq!(a.trunk_digest(), b.trunk_digest());
        let c = mk("c", seed + 1, tau);
        assert_ne!(a.digest(), c.digest(), "digest must see the seed");
        assert_ne!(a.trunk_digest(), c.trunk_digest());
        let other_tau = g.usize(1..total);
        let d = mk("d", seed, other_tau);
        assert_eq!(
            a.trunk_digest() == d.trunk_digest(),
            JobGraph::group_key(&a) == JobGraph::group_key(&d),
            "trunk digest must agree with the sharing rule (τ {tau} vs {other_tau})"
        );
        assert_eq!(a.digest() == d.digest(), tau == other_tau);
    });
}

// ------------------------------------------------------------------ batcher

#[test]
fn prop_batcher_epoch_partition() {
    let corpus = Corpus::generate(CorpusConfig {
        vocab: 64,
        train_tokens: 30_000,
        val_tokens: 1000,
        ..Default::default()
    });
    proptest(20, |g| {
        let seq = *g.choose(&[8usize, 16, 32, 64]);
        let seed = g.usize(0..1000) as u64;
        let mut b = Batcher::new(&corpus.train, seq, seed);
        let n = b.windows_per_epoch();
        let mut seen = std::collections::HashSet::new();
        let mut tokens = 0usize;
        for _ in 0..n {
            let (x, y) = b.next_window();
            assert_eq!(x.len(), seq);
            assert_eq!(&x[1..], &y[..seq - 1], "y must be x shifted");
            assert!(seen.insert(x.as_ptr()), "window repeated within epoch");
            tokens += seq;
        }
        // Epoch covers ~everything (at most seq leftover).
        assert!(corpus.train.len() - tokens <= seq + 1);
    });
}

// ---------------------------------------------------------------- expansion

fn synth_manifest(depths: &[usize]) -> Manifest {
    // Two-matrix-per-layer toy family, enough to exercise remapping.
    let mut cfgs = Vec::new();
    for &n in depths {
        let mut params = vec![
            r#"{"name":"embed.tok","shape":[32,8],"init":"normal","std":0.02,"muon":true,"decay":false,"fan_in":32,"fan_out":8}"#.to_string(),
        ];
        let mut opt = vec![r#"{"name":"mom.embed.tok","shape":[32,8]}"#.to_string()];
        for i in 0..n {
            params.push(format!(
                r#"{{"name":"layer.{i}.norm1.g","shape":[8],"init":"ones","muon":false,"decay":false}}"#
            ));
            params.push(format!(
                r#"{{"name":"layer.{i}.attn.wo","shape":[8,8],"init":"normal","std":0.35,"muon":true,"decay":true,"fan_in":8,"fan_out":8}}"#
            ));
            params.push(format!(
                r#"{{"name":"layer.{i}.mlp.w2","shape":[8,8],"init":"normal","std":0.35,"muon":true,"decay":true,"fan_in":8,"fan_out":8}}"#
            ));
            opt.push(format!(r#"{{"name":"mom.layer.{i}.norm1.g","shape":[8]}}"#));
            opt.push(format!(r#"{{"name":"mom.layer.{i}.attn.wo","shape":[8,8]}}"#));
            opt.push(format!(r#"{{"name":"mom.layer.{i}.mlp.w2","shape":[8,8]}}"#));
        }
        cfgs.push(format!(
            r#""toy.l{n}":{{"model":{{"family":"gpt2","n_layer":{n},"batch":2,"seq_len":8,"moe":null}},
               "opt":{{"kind":"muon_nsgd"}},"params":[{}],"opt_state":[{}],
               "param_count":1,"active_param_count":1,"chunk":8,"artifacts":{{}}}}"#,
            params.join(","),
            opt.join(",")
        ));
    }
    let text = format!(r#"{{"configs":{{{}}}}}"#, cfgs.join(","));
    Manifest::parse(&text, std::path::PathBuf::from("/tmp")).unwrap()
}

#[test]
fn prop_expansion_is_total_and_shape_correct() {
    let m = synth_manifest(&[0, 1, 2, 3, 4, 6, 8]);
    let depths = [0usize, 1, 2, 3, 4, 6, 8];
    proptest(300, |g| {
        let n_src = *g.choose(&depths);
        let n_dst = *g.choose(&depths);
        let strategy = *g.choose(&[
            Strategy::Random,
            Strategy::Zero,
            Strategy::Copying(CopyOrder::Stack),
            Strategy::Copying(CopyOrder::Inter),
            Strategy::Copying(CopyOrder::Last),
            Strategy::CopyingZeroN,
            Strategy::CopyingZeroL,
        ]);
        let spec = ExpandSpec {
            strategy,
            insertion: if g.bool() { Insertion::Bottom } else { Insertion::Top },
            os_policy: *g.choose(&[OsPolicy::Inherit, OsPolicy::Copy, OsPolicy::Reset]),
            seed: g.usize(0..100) as u64,
        };
        let src = m.get(&format!("toy.l{n_src}")).unwrap();
        let dst = m.get(&format!("toy.l{n_dst}")).unwrap();
        let state = ModelState::init(src, 1);
        let result = expand(src, dst, &state, &spec);
        if n_dst < n_src || (!applicable(strategy, n_src) && n_dst > n_src) {
            assert!(result.is_err(), "expected rejection: {n_src}->{n_dst} {strategy:?}");
        } else if n_dst >= n_src && applicable(strategy, n_src) {
            let big = result.unwrap();
            // Bijection onto target manifest: every param has its spec shape.
            assert_eq!(big.params.len(), dst.params.len());
            for (t, spec_p) in big.params.iter().zip(&dst.params) {
                assert_eq!(t.shape, spec_p.shape, "{}", spec_p.name);
            }
            assert_eq!(big.opt.len(), dst.opt_state.len());
            // Old layers preserved bit-exact for order-preserving strategies.
            if matches!(strategy, Strategy::Random | Strategy::Zero | Strategy::CopyingZeroN | Strategy::CopyingZeroL)
                && spec.insertion == Insertion::Bottom
            {
                for (i, spec_p) in dst.params.iter().enumerate() {
                    if spec_p.layer_index().map(|j| j < n_src).unwrap_or(true) {
                        let src_t = state.param(src, &spec_p.name).unwrap();
                        assert_eq!(src_t.data, big.params[i].data, "{}", spec_p.name);
                    }
                }
            }
        }
    });
}

#[test]
fn prop_expansion_random_matches_manifest_std() {
    let m = synth_manifest(&[0, 8]);
    let src = m.get("toy.l0").unwrap();
    let dst = m.get("toy.l8").unwrap();
    let state = ModelState::init(src, 1);
    let big = expand(src, dst, &state, &ExpandSpec::default()).unwrap();
    // New-layer matrices should have empirical std near the manifest's 0.35.
    let mut all = Vec::new();
    for (t, spec) in big.params.iter().zip(&dst.params) {
        if spec.name.ends_with(".wo") || spec.name.ends_with(".w2") {
            all.extend_from_slice(&t.data);
        }
    }
    let n = all.len() as f64;
    let mean: f64 = all.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var: f64 = all.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    let std = var.sqrt();
    assert!((std - 0.35).abs() < 0.03, "std {std}");
}

// -------------------------------------------------------------------- mixing

#[test]
fn prop_mixing_monotone_under_extension() {
    proptest(200, |g| {
        // Build a fixed curve and a progressive curve that converges to it.
        let n = g.usize(6..30);
        let tol = 0.03f32;
        let mut fixed = Curve::new("f");
        let mut prog = Curve::new("p");
        let mix_at = g.usize(2..n);
        for i in 0..n {
            let t = (i * 100) as u64;
            let f = 4.0 - 3.0 * (i as f32 / n as f32);
            let gap = if i >= mix_at { 0.0 } else { 1.0 + g.f32(0.0, 1.0) };
            fixed.push(CurvePoint { step: i, tokens: t, flops: 0.0, train_loss: f, val_loss: f, lr: 0.01 });
            prog.push(CurvePoint { step: i, tokens: t, flops: 0.0, train_loss: f + gap, val_loss: f + gap, lr: 0.01 });
        }
        let before = mixing_point(&prog, &fixed, tol, 2);
        // Extend both with more in-tolerance points: mixing must not un-mix
        // and the mixing point must not move later.
        for i in n..n + 3 {
            let t = (i * 100) as u64;
            fixed.push(CurvePoint { step: i, tokens: t, flops: 0.0, train_loss: 1.0, val_loss: 1.0, lr: 0.01 });
            prog.push(CurvePoint { step: i, tokens: t, flops: 0.0, train_loss: 1.0, val_loss: 1.0, lr: 0.01 });
        }
        let after = mixing_point(&prog, &fixed, tol, 2);
        if let Some(b) = before {
            assert_eq!(after, Some(b), "mixing point moved after appending mixed points");
        }
        if n - mix_at >= 2 {
            assert!(before.is_some(), "should have mixed at {mix_at}/{n}");
        }
        // Progressive points beyond the fixed curve's domain are outside the
        // overlap: appending them — however wild their losses — must not
        // change the verdict (they used to be compared against a
        // flat-extrapolated fixed value, faking or resetting mixing).
        for (i, val) in [(n + 10, 100.0f32), (n + 11, 1.0), (n + 12, 0.9)] {
            prog.push(CurvePoint {
                step: i,
                tokens: (i * 100) as u64,
                flops: 0.0,
                train_loss: val,
                val_loss: val,
                lr: 0.01,
            });
        }
        assert_eq!(
            mixing_point(&prog, &fixed, tol, 2),
            after,
            "out-of-overlap points must not move the mixing point"
        );
    });
}

#[test]
fn prop_mixing_is_none_for_non_overlapping_curves() {
    proptest(200, |g| {
        // The fixed curve spans [0, 100·(n−1)] tokens; the progressive one
        // starts strictly past its end (or vice versa). With no overlap
        // there is nothing to compare — even an infinitely loose tolerance
        // must not report mixing.
        let n = g.usize(1..10);
        let m = g.usize(1..10);
        let gap = g.usize(1..1000) as u64;
        let mut fixed = Curve::new("f");
        let mut prog = Curve::new("p");
        let fixed_end = (n - 1) as u64 * 100;
        for i in 0..n {
            let v = g.f32(0.5, 5.0);
            fixed.push(CurvePoint { step: i, tokens: i as u64 * 100, flops: 0.0, train_loss: v, val_loss: v, lr: 0.01 });
        }
        for j in 0..m {
            let v = g.f32(0.5, 5.0);
            let tokens = fixed_end + gap + j as u64 * 100;
            prog.push(CurvePoint { step: j, tokens, flops: 0.0, train_loss: v, val_loss: v, lr: 0.01 });
        }
        assert_eq!(mixing_point(&prog, &fixed, f32::INFINITY, 1), None);
        assert_eq!(mixing_point(&fixed, &prog, f32::INFINITY, 1), None);
        assert_eq!(mixing_point(&prog, &fixed, 0.05, 2), None);
        assert_eq!(mixing_point(&fixed, &prog, 0.05, 2), None);
    });
}

// ---------------------------------------------------------------------- json

#[test]
fn prop_json_roundtrip() {
    proptest(200, |g| {
        // Random JSON value generator (depth-bounded).
        fn gen_val(g: &mut deep_progressive::util::proptest::Gen, depth: usize) -> Json {
            use std::collections::BTreeMap;
            let pick = if depth == 0 { g.usize(0..4) } else { g.usize(0..6) };
            match pick {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64(-1e6, 1e6) * 1000.0).round() / 1000.0),
                3 => Json::Str(format!("s{}-\"esc\\ape\"\n{}", g.usize(0..100), g.usize(0..10))),
                4 => {
                    let k = g.usize(0..5);
                    Json::Arr((0..k).map(|_| gen_val(g, depth - 1)).collect())
                }
                _ => {
                    let mut m = BTreeMap::new();
                    let k = g.usize(0..5);
                    for i in 0..k {
                        let v = gen_val(g, depth - 1);
                        m.insert(format!("k{i}"), v);
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = gen_val(g, 3);
        let text = v.to_string();
        let v2 = Json::parse(&text).unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(v, v2);
    });
}
